"""repro.obs: tracing, metrics, watchdog, and the no-op-when-off contract."""
import dataclasses
import json
import warnings

import jax
import numpy as np
import pytest

from repro.core import LDAConfig
from repro.core.engines import LDAEngine
from repro.core.metrics import _npmi_coherence_loop, npmi_coherence
from repro.data import PAPER_CORPORA, make_corpus
from repro.lda import LDA
from repro.obs import (NULL_TELEMETRY, BoundMonotonicityError, ElboWatchdog,
                       ElboMonotonicityWarning, MetricsRegistry, SpanRecorder,
                       Telemetry, as_telemetry, chrome_trace_from_jsonl,
                       load_jsonl, spans_by_name, validate_jsonl)


@pytest.fixture(scope="module")
def tiny_corpus():
    spec = PAPER_CORPORA["tiny"]
    return make_corpus(spec, split="train", seed=0), spec


# ---------------------------------------------------------------------------
# trace
# ---------------------------------------------------------------------------

def test_span_recorder_nesting_and_roundtrip(tmp_path):
    rec = SpanRecorder()
    with rec.span("outer", phase="a"):
        with rec.span("inner"):
            pass
        rec.event("marker", n=3)
    tok = rec.begin("manual")
    rec.end(tok)
    assert rec.num_records == 4
    by_name = {r["name"]: r for r in rec.records}
    assert by_name["inner"]["depth"] == 1
    assert by_name["outer"]["depth"] == 0
    assert by_name["outer"]["dur_us"] >= by_name["inner"]["dur_us"]
    assert by_name["marker"]["type"] == "event"

    jsonl = str(tmp_path / "t.jsonl")
    chrome = str(tmp_path / "t.chrome.json")
    assert rec.dump_jsonl(jsonl) == 4
    assert validate_jsonl(jsonl) == 4
    # Chrome conversion is count-exact: 1 record -> 1 traceEvent
    assert chrome_trace_from_jsonl(jsonl, chrome) == 4
    with open(chrome) as f:
        ct = json.load(f)
    assert len(ct["traceEvents"]) == 4
    assert {e["ph"] for e in ct["traceEvents"]} == {"X", "i"}


def test_validate_rejects_malformed(tmp_path):
    rec = SpanRecorder()
    rec.event("ok")
    jsonl = str(tmp_path / "bad.jsonl")
    rec.dump_jsonl(jsonl)
    meta, records = load_jsonl(jsonl)
    records[0].pop("ts_us")
    with open(jsonl, "w") as f:
        f.write(json.dumps(meta) + "\n")
        for r in records:
            f.write(json.dumps(r) + "\n")
    with pytest.raises(ValueError, match="missing 'ts_us'"):
        validate_jsonl(jsonl)


def test_spans_by_name_aggregates():
    rec = SpanRecorder()
    for _ in range(3):
        with rec.span("train/solve"):
            pass
    agg = spans_by_name(rec.records)
    assert agg["train/solve"]["count"] == 3
    assert agg["train/solve"]["min_s"] <= agg["train/solve"]["mean_s"]


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_metrics_counters_gauges_labels():
    m = MetricsRegistry()
    m.inc("train.batches", width=64)
    m.inc("train.batches", width=64)
    m.inc("train.batches", width=128)
    assert m.value("train.batches", width=64) == 2.0
    assert m.total("train.batches") == 3.0
    m.set_gauge("pack.pad_frac", 0.25, width=64)
    m.set_gauge("pack.pad_frac", 0.5, width=64)       # gauges overwrite
    assert m.value("pack.pad_frac", width=64) == 0.5
    snap = m.snapshot()
    assert any(c["name"] == "train.batches" and c["labels"] == {"width": 128}
               for c in snap["counters"])


def test_metrics_percentiles_and_empty():
    m = MetricsRegistry()
    for v in range(1, 101):
        m.observe("lat", float(v))
    pct = m.percentiles("lat")
    assert pct["p50"] == pytest.approx(50.5)
    assert pct["p99"] == pytest.approx(np.percentile(np.arange(1, 101), 99))
    empty = m.percentiles("nothing")
    assert all(np.isnan(v) for v in empty.values())
    assert m.histogram_values("nothing") == []


# ---------------------------------------------------------------------------
# watchdog
# ---------------------------------------------------------------------------

def test_watchdog_warns_then_raises_on_injected_decrease():
    wd = ElboWatchdog(policy="warn", tol=1e-6)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert not wd.observe(-100.0, step=1)
        assert not wd.observe(-99.0, step=2)          # increase: fine
        assert wd.observe(-99.5, step=3)              # injected decrease
    assert len(w) == 1 and issubclass(w[0].category, ElboMonotonicityWarning)
    assert wd.status()["violations"] == 1 and not wd.status()["ok"]

    hard = ElboWatchdog(policy="raise", tol=1e-6)
    hard.observe(-100.0, step=1)
    with pytest.raises(BoundMonotonicityError, match="monotonicity"):
        hard.observe(-101.0, step=2)


def test_watchdog_unarmed_and_slack():
    wd = ElboWatchdog(policy="raise", tol=1e-6)
    # unarmed readings (random-init mass still retiring) never enforce
    wd.observe(-100.0, armed=False)
    assert not wd.observe(-200.0, armed=False)
    # an armed reading right after an unarmed one has no armed baseline
    assert not wd.observe(-300.0, armed=True)
    # within-slack jitter passes: slack = max(tol, rel_tol * |prev|)
    loose = ElboWatchdog(policy="raise", tol=5e-3)
    loose.observe(-100.0)
    assert not loose.observe(-100.004)
    assert wd.status()["armed_checks"] == 1


def test_watchdog_counts_into_metrics_and_cadence():
    m = MetricsRegistry()
    wd = ElboWatchdog(policy="warn", tol=1e-6, check_every=4, metrics=m)
    assert not wd.should_check(3)
    assert wd.should_check(8)
    assert not ElboWatchdog(check_every=0).should_check(7)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        wd.observe(-1.0)
        wd.observe(-2.0)
    assert m.value("watchdog.violations") == 1.0
    assert wd.bound_tail(1) == [-2.0]


# ---------------------------------------------------------------------------
# the bundle and the null object
# ---------------------------------------------------------------------------

def test_as_telemetry_coercions():
    assert as_telemetry(None) is NULL_TELEMETRY
    assert as_telemetry(False) is NULL_TELEMETRY
    t = as_telemetry(True)
    assert isinstance(t, Telemetry) and t.enabled
    assert t.watchdog.check_every == 0     # default: observe at evaluate()
    assert t.watchdog.metrics is t.metrics  # bundle wires them together
    assert as_telemetry(t) is t
    with pytest.raises(TypeError):
        as_telemetry("yes")


def test_null_telemetry_is_inert():
    assert not NULL_TELEMETRY.enabled
    assert NULL_TELEMETRY.trace.begin("x") is None
    NULL_TELEMETRY.trace.end(None)
    NULL_TELEMETRY.metrics.inc("x")
    assert NULL_TELEMETRY.trace.num_records == 0
    assert NULL_TELEMETRY.trace.records == []
    assert NULL_TELEMETRY.metrics.snapshot() == {"counters": [], "gauges": [],
                                                 "histograms": []}
    assert not NULL_TELEMETRY.watchdog.observe(-1e9)


# ---------------------------------------------------------------------------
# integration: telemetry off is a true no-op; on catches real regressions
# ---------------------------------------------------------------------------

def test_disabled_telemetry_is_noop_bit_identical(tiny_corpus):
    corpus, spec = tiny_corpus
    cfg = LDAConfig(num_topics=4, vocab_size=spec.vocab_size,
                    estep_max_iters=15)
    plain = LDAEngine(cfg, corpus, algo="ivi", batch_size=16, seed=0)
    nulled = LDAEngine(cfg, corpus, algo="ivi", batch_size=16, seed=0,
                       telemetry=None)
    for _ in range(2):
        plain.run_epoch()
        nulled.run_epoch()
    assert np.array_equal(np.asarray(plain.state.lam),
                          np.asarray(nulled.state.lam))
    assert nulled.tel is NULL_TELEMETRY
    assert nulled.tel.trace.num_records == 0


def test_enabled_telemetry_matches_and_records(tiny_corpus):
    corpus, spec = tiny_corpus
    cfg = LDAConfig(num_topics=4, vocab_size=spec.vocab_size,
                    estep_max_iters=15)
    plain = LDAEngine(cfg, corpus, algo="ivi", batch_size=16, seed=0)
    tel = Telemetry()
    traced = LDAEngine(cfg, corpus, algo="ivi", batch_size=16, seed=0,
                       telemetry=tel)
    plain.run_epoch()
    traced.run_epoch()
    assert np.array_equal(np.asarray(plain.state.lam),
                          np.asarray(traced.state.lam))
    n_batches = -(-corpus.num_docs // 16)
    assert tel.metrics.total("train.docs") == corpus.num_docs
    assert tel.metrics.total("train.batches") == n_batches
    assert tel.metrics.total("train.tokens") > 0
    assert tel.metrics.value("train.memo_resident_bytes") > 0
    agg = spans_by_name(tel.trace.records)
    for name in ("train/update", "train/memo_gather", "train/solve",
                 "train/memo_update"):
        assert agg[name]["count"] == n_batches, name
    # evaluate() feeds the watchdog at the free cadence + the topic gauge
    traced.evaluate()
    assert tel.watchdog.status()["checks"] == 1
    assert tel.metrics.value("train.effective_topics") > 0


def test_watchdog_catches_real_bound_decrease(tiny_corpus):
    """Corrupting the memo mid-run breaks eq. 4's subtract-old bookkeeping —
    exactly the failure class the watchdog exists for — and the next armed
    per-update check must raise."""
    corpus, spec = tiny_corpus
    cfg = LDAConfig(num_topics=4, vocab_size=spec.vocab_size,
                    estep_max_iters=15)
    tel = Telemetry(watchdog=ElboWatchdog(policy="raise", check_every=1))
    eng = LDAEngine(cfg, corpus, algo="ivi", batch_size=16, seed=0,
                    telemetry=tel)
    eng.run_epoch()                       # retires init mass -> armed
    eng.run_epoch()                       # a full armed epoch: no violation
    assert float(jax.device_get(eng.state.init_frac)) == 0.0
    assert tel.watchdog.status()["armed_checks"] > 0
    assert tel.watchdog.status()["ok"]
    # corrupt λ out from under the memoized statistics
    eng.state = dataclasses.replace(
        eng.state, lam=eng.state.lam[:, ::-1] * 7.0 + 11.0)
    with pytest.raises(BoundMonotonicityError):
        eng.run_epoch()
    assert tel.watchdog.status()["violations"] >= 1


# ---------------------------------------------------------------------------
# facade surface + vectorized coherence
# ---------------------------------------------------------------------------

def test_lda_facade_telemetry_and_metrics(tiny_corpus):
    corpus, spec = tiny_corpus
    lda = LDA(num_topics=4, vocab_size=spec.vocab_size, estep_max_iters=15,
              algo="ivi", batch_size=16, seed=0, telemetry=True)
    lda.fit(corpus, epochs=1)
    assert lda.telemetry.metrics.total("train.docs") == corpus.num_docs
    assert lda.telemetry.summary()["trace_records"] > 0
    assert lda.effective_topics() > 1.0
    c = lda.coherence(corpus, k=5)
    assert -1.0 <= c <= 1.0


def test_npmi_vectorized_equals_loop(tiny_corpus):
    corpus, spec = tiny_corpus
    rng = np.random.default_rng(3)
    lam = rng.gamma(2.0, 1.0, size=(spec.vocab_size, 6)).astype(np.float32)
    fast = npmi_coherence(lam, corpus, k=6)
    slow = _npmi_coherence_loop(lam, corpus, k=6)
    assert fast == pytest.approx(slow, abs=1e-12)
