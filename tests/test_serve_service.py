"""The `repro.serve` subsystem: admission edge cases, snapshot swaps,
online learning, and the serving loop end to end (`docs/serving.md`).

The controller is clock-free (every method takes an explicit ``now``),
so every admission edge case here is deterministic — no sleeps, no
real-clock races. The served-vs-offline equality tests are the bit-level
contract the admission packer rides on: a batch formed from the request
stream is the SAME batch ``posterior_docs`` would have packed.
"""
from __future__ import annotations

import math
import threading

import numpy as np
import pytest

from repro.core.math import exp_dirichlet_expectation
from repro.data import PAPER_CORPORA, make_corpus
from repro.data.stream import BatchPacker, CorpusDocStream, QueueDocStream
from repro.lda import LDA
from repro.obs import ElboWatchdog
from repro.serve import (
    AdmissionController,
    OnlineLearner,
    Request,
    ServiceConfig,
    ServingService,
    SnapshotStore,
    onoff_arrivals,
    poisson_arrivals,
    replay_arrivals,
    requests_from_docs,
    validate_slo_report,
)

SPEC = PAPER_CORPORA["tiny"]


def _ragged(n_docs, *, vocab=SPEC.vocab_size, max_n=24, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_docs):
        n = int(rng.integers(2, max_n))
        ids = np.sort(rng.choice(vocab, size=n, replace=False)).astype(
            np.int32)
        cnts = (rng.poisson(1.0, n) + 1).astype(np.float32)
        out.append((ids, cnts))
    return out


@pytest.fixture(scope="module")
def tiny_lda():
    train = make_corpus(SPEC, split="train", seed=0, scale=0.25)
    lda = LDA(num_topics=4, vocab_size=SPEC.vocab_size, estep_max_iters=10,
              algo="ivi", seed=0)
    lda.fit(train, epochs=1)
    return lda


@pytest.fixture()
def inf(tiny_lda):
    return tiny_lda.inferencer(batch_size=8)


# ---------------------------------------------------------------------------
# traffic generators
# ---------------------------------------------------------------------------

def test_poisson_arrivals_seeded_and_sorted():
    a = poisson_arrivals(64, 100.0, seed=3)
    b = poisson_arrivals(64, 100.0, seed=3)
    c = poisson_arrivals(64, 100.0, seed=4)
    assert len(a) == 64
    assert np.array_equal(a, b)                  # seeded: reproducible
    assert not np.array_equal(a, c)
    assert np.all(np.diff(a) >= 0)               # a schedule, sorted
    # mean gap ~ 1/rate (loose: 64 samples)
    assert 0.3 / 100.0 < np.diff(a).mean() < 3.0 / 100.0


def test_onoff_arrivals_burst_structure():
    a = onoff_arrivals(80, 200.0, on_s=0.02, off_s=1.0, seed=0)
    assert len(a) == 80 and np.all(np.diff(a) >= 0)
    assert np.array_equal(a, onoff_arrivals(80, 200.0, on_s=0.02,
                                            off_s=1.0, seed=0))
    # the OFF gaps are visible: some inter-arrival jumps span a full
    # silent period, while within a burst gaps stay Poisson-small
    gaps = np.diff(a)
    assert gaps.max() >= 1.0
    assert gaps.min() < 0.02


def test_replay_arrivals():
    assert np.all(np.asarray(replay_arrivals(5)) == 0.0)
    spaced = np.asarray(replay_arrivals(5, 10.0))
    assert np.allclose(np.diff(spaced), 0.1)


def test_requests_from_docs_cycles_and_deadlines():
    docs = _ragged(3, seed=1)
    arr = [0.0, 0.1, 0.2, 0.3, 0.4]
    reqs = requests_from_docs(docs, arr, deadline_s=0.5, start_id=7)
    assert [r.rid for r in reqs] == [7, 8, 9, 10, 11]
    assert np.array_equal(reqs[3].ids, docs[0][0])      # cycles
    assert all(r.deadline_s == pytest.approx(r.arrival_s + 0.5)
               for r in reqs)
    inf_reqs = requests_from_docs(docs, arr[:2])
    assert all(math.isinf(r.deadline_s) for r in inf_reqs)


# ---------------------------------------------------------------------------
# admission control (clock-free: all edge cases deterministic)
# ---------------------------------------------------------------------------

_KW = dict(batch_size=4, vocab_size=SPEC.vocab_size, layout="padded",
           token_budget=None)


def _req(rid, doc, arrival=0.0, deadline=math.inf):
    ids, cnts = doc
    return Request(rid=rid, ids=ids, cnts=cnts, arrival_s=arrival,
                   deadline_s=deadline)


def test_empty_flush_window_never_flushes():
    ac = AdmissionController(_KW, flush_timeout_s=0.01)
    assert ac.poll(now=1e9) == []            # nothing pending: no flush
    assert ac.next_due(now=0.0) is None
    assert ac.close(now=0.0) == []
    assert ac.pending == 0


def test_full_bucket_emits_on_offer():
    ac = AdmissionController(_KW, flush_timeout_s=10.0)
    docs = [( np.arange(6, dtype=np.int32),
              np.ones(6, np.float32)) for _ in range(4)]
    batches = []
    for i, d in enumerate(docs):
        admitted, batch = ac.offer(_req(i, d), now=0.0)
        assert admitted
        if batch is not None:
            batches.append(batch)
    assert len(batches) == 1                 # emitted the moment it filled
    assert len(batches[0].rows) == 4
    reqs = ac.take(batches[0].rows, now=0.0)
    assert [r.rid for r in reqs] == [0, 1, 2, 3]
    assert ac.pending == 0


def test_timeout_partial_flush():
    ac = AdmissionController(_KW, flush_timeout_s=0.05)
    admitted, batch = ac.offer(_req(0, _ragged(1, seed=2)[0]), now=0.0)
    assert admitted and batch is None
    assert ac.poll(now=0.049) == []          # not due yet
    out = ac.poll(now=0.05)                  # oldest waited the timeout
    assert len(out) == 1 and len(out[0].rows) == 1
    assert [r.rid for r in ac.take(out[0].rows, now=0.05)] == [0]
    assert ac.poll(now=1.0) == []            # window empty again


def test_over_deadline_request_is_shed():
    ac = AdmissionController(_KW, shed_margin_s=0.01)
    doc = _ragged(1, seed=3)[0]
    admitted, batch = ac.offer(_req(0, doc, deadline=1.0), now=0.995)
    assert not admitted and batch is None    # inside the shed margin
    assert [r.rid for r in ac.shed] == [0]
    assert ac.pending == 0 and ac.offered == 1
    admitted, _ = ac.offer(_req(1, doc, deadline=1.0), now=0.5)
    assert admitted                          # plenty of budget left


def test_deadline_headroom_flushes_before_timeout():
    ac = AdmissionController(_KW, flush_timeout_s=10.0,
                             deadline_headroom_s=0.02)
    ac.offer(_req(0, _ragged(1, seed=4)[0], deadline=1.0), now=0.0)
    assert ac.poll(now=0.5) == []            # deadline still far
    assert len(ac.poll(now=0.985)) == 1      # within the headroom
    assert ac.next_due(now=0.0) == pytest.approx(0.98)  # deadline-driven


def test_next_due_is_sleep_horizon():
    ac = AdmissionController(_KW, flush_timeout_s=0.05)
    ac.offer(_req(0, _ragged(1, seed=5)[0]), now=1.0)
    assert ac.next_due(now=1.0) == pytest.approx(1.05)
    assert ac.next_due(now=2.0) == 2.0       # already due: clamped to now


def test_csr_over_budget_doc_at_head_of_flush_serves_clipped():
    kw = dict(batch_size=4, vocab_size=SPEC.vocab_size, layout="csr",
              token_budget=16)
    ac = AdmissionController(kw, flush_timeout_s=0.05)
    ids = np.arange(40, dtype=np.int32)          # 40 uniques > budget 16
    cnts = np.arange(1, 41, dtype=np.float32)
    admitted, batch = ac.offer(_req(0, (ids, cnts)), now=0.0)
    assert admitted and batch is None            # clipped, filed — no wedge
    out = ac.poll(now=0.05)
    assert len(out) == 1
    b = out[0]
    live = int((b.counts > 0).sum())
    assert live == 16                            # clipped to the budget
    # the clip keeps the most frequent tokens (corpus_from_docs rule)
    assert set(np.asarray(b.token_ids)[np.asarray(b.counts) > 0]) \
        == set(range(24, 40))
    assert [r.rid for r in ac.take(b.rows, now=0.05)] == [0]
    assert ac.pending == 0


# ---------------------------------------------------------------------------
# snapshot swaps (satellite: thread-safe swap_model, in-flight semantics)
# ---------------------------------------------------------------------------

def _uniform_docs(n_docs, *, n_tokens=6, seed=0):
    """Same-width docs: they all file into ONE ladder bucket, so a flush
    yields exactly one batch (what `_pack_one_batch` requires)."""
    rng = np.random.default_rng(seed)
    return [(np.sort(rng.choice(SPEC.vocab_size, size=n_tokens,
                                replace=False)).astype(np.int32),
             (rng.poisson(1.0, n_tokens) + 1).astype(np.float32))
            for _ in range(n_docs)]


def _pack_one_batch(inf, docs):
    kw = inf.packer_kwargs()
    packer = BatchPacker(kw["batch_size"], vocab_size=kw["vocab_size"],
                         layout=kw["layout"], token_budget=kw["token_budget"])
    batches = []
    for pos, (ids, cnts) in enumerate(docs):
        b = packer.add(pos, ids, cnts)
        if b is not None:
            batches.append(b)
    batches.extend(packer.flush())
    assert len(batches) == 1
    return batches[0]


def test_swap_model_validation(inf, tiny_lda):
    lam = np.asarray(tiny_lda.lam)
    with pytest.raises(ValueError):
        inf.swap_model()                         # neither lam nor eb
    with pytest.raises(ValueError):
        inf.swap_model(lam, exp_elog_beta=inf.exp_elog_beta)   # both
    with pytest.raises(ValueError):
        inf.swap_model(lam[:-1])                 # shape change
    v1 = inf.swap_model(lam * 1.5)
    assert v1 == 1 and inf.model_version == 1
    with pytest.raises(ValueError):
        inf.swap_model(lam, version=1)           # version must advance
    eb = np.asarray(exp_dirichlet_expectation(lam * 1.5, axis=0))
    assert np.allclose(np.asarray(inf.exp_elog_beta), eb)


def test_in_flight_batch_completes_on_old_snapshot(tiny_lda, monkeypatch):
    """A swap landing mid-dispatch must NOT leak into the running batch:
    `_dispatch` reads the (version, Eφ) tuple exactly once, so the batch
    completes — and reports — the snapshot it started on."""
    import repro.lda.infer as infer_mod

    lam1 = np.asarray(tiny_lda.lam)
    lam2 = lam1 * 2.0
    docs = _uniform_docs(5, seed=6)
    inf = tiny_lda.inferencer(batch_size=8)
    ref_old = tiny_lda.inferencer(batch_size=8)      # frozen at lam1
    batch = _pack_one_batch(inf, docs)
    _, g_old, n, v_old = ref_old.posterior_packed(batch)
    g_old = np.asarray(g_old)

    real = infer_mod._posterior_batch
    fired = []

    def swap_mid_dispatch(cfg, eb, ids, cnts):
        if not fired:                        # swap lands mid-flight, once
            fired.append(inf.swap_model(lam2))
        return real(cfg, eb, ids, cnts)

    monkeypatch.setattr(infer_mod, "_posterior_batch", swap_mid_dispatch)
    _, gamma, n2, version = inf.posterior_packed(batch)
    assert fired == [1]                      # the swap really happened
    assert version == v_old == 0             # ...but this batch predates it
    assert n2 == n
    assert np.array_equal(np.asarray(gamma), g_old)   # served on old Eφ
    monkeypatch.undo()
    assert inf.model_version == 1            # the NEXT batch sees the swap
    _, g_new, _, v_new = inf.posterior_packed(batch)
    assert v_new == 1
    assert not np.array_equal(np.asarray(g_new), g_old)


def test_concurrent_swaps_never_tear(tiny_lda):
    """Hammer swap_model from a writer thread while serving: every result's
    γ must be bit-equal to the single published λ its version names —
    a torn read (version from one snapshot, Eφ from another) would fail."""
    lam1 = np.asarray(tiny_lda.lam)
    lams = {0: lam1}
    inf = tiny_lda.inferencer(batch_size=8)
    batch = _pack_one_batch(inf, _uniform_docs(6, seed=7))

    n_swaps = 40
    rng = np.random.default_rng(8)
    for v in range(1, n_swaps + 1):
        lams[v] = lam1 * float(rng.uniform(1.1, 3.0))
    stop = threading.Event()
    seen = []

    def read_one():
        _, gamma, _, version = inf.posterior_packed(batch)
        seen.append((version, np.asarray(gamma)))

    def writer():
        for v in range(1, n_swaps + 1):
            inf.swap_model(lams[v], version=v)
        stop.set()

    read_one()                               # version 0, before any swap
    t = threading.Thread(target=writer)
    t.start()
    while not stop.is_set():
        read_one()                           # racing the swaps
    t.join()
    read_one()                               # final version, after all swaps

    refs = {}
    for version, gamma in seen:
        if version not in refs:
            ref = tiny_lda.inferencer(batch_size=8)
            if version:
                ref.swap_model(lams[version], version=version)
            refs[version] = np.asarray(ref.posterior_packed(batch)[1])
        assert np.array_equal(gamma, refs[version]), \
            f"torn snapshot at version {version}"
    # bracketing reads make ≥ 2 distinct versions deterministic
    assert {0, n_swaps} <= {v for v, _ in seen}


def test_snapshot_store_publish(inf, tiny_lda):
    store = SnapshotStore(inf)
    lam = np.asarray(tiny_lda.lam) * 1.2
    snap = store.publish(lam, docs_trained=17)
    assert snap.version == 1 == inf.model_version
    assert snap.docs_trained == 17
    assert store.current is snap
    assert snap.swap_stall_s >= 0.0
    assert len(store.swap_stalls_ms()) == 1
    unattached = SnapshotStore()
    with pytest.raises(ValueError):
        unattached.publish(lam)


# ---------------------------------------------------------------------------
# QueueDocStream (the request-queue → DocStream bridge)
# ---------------------------------------------------------------------------

def test_queue_stream_capacity_and_positions():
    qs = QueueDocStream(100, capacity=3)
    docs = _ragged(5, vocab=100, seed=9)
    pos = [qs.append(d) for d in docs]
    assert pos == [0, 1, 2, None, None]      # stable slots, then full
    assert qs.num_docs == 3                  # capacity: the memo size
    assert qs.appended == 3 and qs.dropped == 2
    got = list(qs.iter_from(0))
    assert len(got) == 3
    assert np.array_equal(got[1][0], docs[1][0])


def test_queue_stream_iterator_sees_late_appends():
    qs = QueueDocStream(100, capacity=8)
    docs = _ragged(4, vocab=100, seed=10)
    qs.append(docs[0])
    it = qs.iter_from(0)
    assert np.array_equal(next(it)[0], docs[0][0])
    for d in docs[1:]:
        qs.append(d)                          # appended AFTER iter started
    rest = list(it)
    assert len(rest) == 3                     # the open window grew
    assert qs.num_words == pytest.approx(
        sum(float(c.sum()) for _, c in docs))


def test_queue_stream_clips_to_max_unique():
    qs = QueueDocStream(1000, capacity=2, max_unique=4)
    ids = np.arange(10, dtype=np.int32)
    cnts = np.arange(1, 11, dtype=np.float32)
    qs.append((ids, cnts))
    (got_ids, got_cnts), = list(qs.iter_from(0))
    assert len(got_ids) == 4
    assert set(got_ids.tolist()) == {6, 7, 8, 9}   # most frequent kept
    assert qs.num_words == pytest.approx(float(got_cnts.sum()))
    with pytest.raises(ValueError):
        qs.append((np.array([1000], np.int32),
                   np.ones(1, np.float32)))        # vocab check


# ---------------------------------------------------------------------------
# OnlineLearner
# ---------------------------------------------------------------------------

def test_online_learner_gating_and_publish(tiny_lda):
    inf = tiny_lda.inferencer(batch_size=8)
    store = SnapshotStore(inf)
    learner = OnlineLearner(tiny_lda.cfg, store,
                            lam0=np.asarray(tiny_lda.lam),
                            min_new_docs=4, batch_size=8, seed=0)
    assert learner.update_once() is None          # no traffic yet
    assert learner.update_once(force=True) is None
    assert learner.observe(_ragged(2, seed=11)) == 2
    assert learner.update_once() is None          # below min_new_docs
    learner.observe(_ragged(3, seed=12))
    v = learner.update_once()                     # 5 ≥ 4: a pass runs
    assert v == 1 and inf.model_version == 1
    assert learner.docs_trained == 5
    assert learner.update_once() is None          # nothing new again
    assert learner.update_once(force=True) == 2   # drain path still runs


def test_online_learner_drain_arms_watchdog(tiny_lda):
    inf = tiny_lda.inferencer(batch_size=8)
    store = SnapshotStore(inf)
    wd = ElboWatchdog(policy="warn")
    learner = OnlineLearner(tiny_lda.cfg, store,
                            lam0=np.asarray(tiny_lda.lam),
                            min_new_docs=4, batch_size=8, watchdog=wd,
                            seed=0)
    learner.observe(_ragged(12, seed=13))
    versions = learner.drain(passes=3)
    assert versions == [1, 2, 3]
    # pass 1 trains on a fresh window (unarmed); 2 and 3 revisit the SAME
    # window with the init mass retired — the armed monotone readings
    assert learner.armed_observations >= 1
    assert wd.violations == []
    armed = [r for r in wd.history if r["armed"]]
    assert all(r["delta"] is None or r["delta"] >= -wd.tol for r in armed)


# ---------------------------------------------------------------------------
# the serving loop end to end
# ---------------------------------------------------------------------------

def test_service_replay_matches_offline_bit_equal(tiny_lda):
    """Served γ == offline ``posterior_docs`` γ, document for document —
    the admission packer forms the SAME batches the offline path packs."""
    inf = tiny_lda.inferencer(batch_size=8)
    docs = _ragged(13, seed=14)               # 13: forces a partial flush
    offline = np.asarray(inf.posterior_docs(docs))
    svc = ServingService(inf, config=ServiceConfig(flush_timeout_s=0.01))
    reqs = requests_from_docs(docs, replay_arrivals(len(docs)))
    responses = svc.run(reqs)
    assert len(responses) == len(docs)
    assert all(r.ok for r in responses)
    for r in responses:
        assert r.model_version == inf.model_version
        assert np.array_equal(r.gamma, offline[r.rid]), \
            f"served γ diverged from offline for rid {r.rid}"
    rep = validate_slo_report(svc.slo_report())
    assert rep["served"] == len(docs) and rep["shed"] == 0
    assert rep["conservation_ok"] and rep["every_response_versioned"]


def test_service_sheds_expired_deadlines(tiny_lda):
    inf = tiny_lda.inferencer(batch_size=8)
    docs = _ragged(6, seed=15)
    # deadline == arrival: by the time the loop offers it, it's expired
    reqs = requests_from_docs(docs, replay_arrivals(len(docs)),
                              deadline_s=0.0)
    svc = ServingService(inf, config=ServiceConfig(flush_timeout_s=0.01))
    responses = svc.run(reqs)
    assert all(r.status == "shed" for r in responses)
    assert all(r.model_version is None and r.gamma is None
               for r in responses)
    rep = validate_slo_report(svc.slo_report())
    assert rep["shed"] == len(docs) and rep["served"] == 0
    assert rep["conservation_ok"]
    assert math.isnan(rep["latency_ms"]["p50"])


def test_service_csr_layout_end_to_end(tiny_lda):
    """The CSR admission path serves — including an over-budget document
    at the head of the stream (clipped, never wedged)."""
    inf = tiny_lda.inferencer(batch_size=8, layout="csr", token_budget=64)
    big_ids = np.sort(np.random.default_rng(16).choice(
        SPEC.vocab_size, size=100, replace=False)).astype(np.int32)
    docs = [(big_ids, np.ones(100, np.float32))] + _ragged(7, seed=17)
    svc = ServingService(inf, config=ServiceConfig(flush_timeout_s=0.01))
    responses = svc.run(requests_from_docs(docs, replay_arrivals(len(docs))))
    assert len(responses) == len(docs) and all(r.ok for r in responses)
    rep = validate_slo_report(svc.slo_report())
    assert rep["conservation_ok"] and rep["served"] == len(docs)


def test_service_online_versions_advance(tiny_lda):
    """End to end with the learner: versions advance mid-stream, every OK
    response is versioned, and served versions ⊆ published versions."""
    inf = tiny_lda.inferencer(batch_size=8)
    store = SnapshotStore(inf)
    learner = OnlineLearner(tiny_lda.cfg, store,
                            lam0=np.asarray(tiny_lda.lam),
                            min_new_docs=4, batch_size=8, seed=0)
    svc = ServingService(inf, config=ServiceConfig(flush_timeout_s=0.005),
                         learner=learner)
    docs = _ragged(24, seed=18)
    reqs = requests_from_docs(docs, poisson_arrivals(len(docs), 400.0,
                                                     seed=0))
    # serve in two waves with a synchronous update in between — the swap
    # lands mid-stream deterministically (no background-thread timing)
    svc.run(reqs[:12])
    assert learner.update_once(force=True) == 1
    svc.run(reqs[12:])
    learner.drain(passes=2)
    rep = validate_slo_report(svc.slo_report())
    assert rep["every_response_versioned"]
    versions = {r.model_version for r in svc.responses if r.ok}
    assert versions >= {0, 1}                # both snapshots served traffic
    assert max(versions) <= inf.model_version
    assert store.current.version == inf.model_version
    assert max(store.swap_stalls_ms()) < 50.0


def test_slo_report_attainment_and_validation(tiny_lda):
    inf = tiny_lda.inferencer(batch_size=8)
    svc = ServingService(inf, config=ServiceConfig(
        flush_timeout_s=0.01, slo_ms={"p95": 1e6}))
    docs = _ragged(5, seed=19)
    svc.run(requests_from_docs(docs, replay_arrivals(len(docs))))
    rep = validate_slo_report(svc.slo_report())
    assert rep["slo"]["p95"]["attained"]          # 1e6 ms: trivially met
    assert rep["slo"]["p95"]["target_ms"] == 1e6

    bad = dict(rep, schema="bogus/v0")
    with pytest.raises(ValueError, match="schema"):
        validate_slo_report(bad)
    bad = dict(rep, served=rep["served"] + 1, conservation_ok=False)
    with pytest.raises(ValueError, match="conservation"):
        validate_slo_report(bad)
    bad = dict(rep, latency_ms={"p50": 1.0})
    with pytest.raises(ValueError, match="p95"):
        validate_slo_report(bad)
    bad = dict(rep, offered="3")
    with pytest.raises(ValueError, match="offered"):
        validate_slo_report(bad)
