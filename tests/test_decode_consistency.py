"""Teacher-forced decode must reproduce the training forward exactly —
validates KV ring buffers, rope-at-insert, sliding windows, recurrent
chunked-scan ↔ single-step equivalence, MoE decode routing."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import transformer as T

S = 32
B = 2


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_matches_train_forward(arch, rng):
    cfg = dataclasses.replace(ARCHS[arch].reduced(seq_len_hint=S),
                              dtype="float32")
    params = T.init_params(cfg, jax.random.key(0))
    tok_shape = ((B, S, cfg.num_codebooks) if cfg.modality == "audio"
                 else (B, S))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, tok_shape))
    logits_train, _ = jax.jit(
        lambda p, b: T.forward(cfg, p, b))(params, {"tokens": tokens})
    caches = T.init_caches(cfg, B, S, dtype=jnp.float32)
    dec = jax.jit(lambda p, c, t, q: T.decode_step(cfg, p, c, t, q))
    outs = []
    for t in range(S):
        lg, caches = dec(params, caches, tokens[:, t],
                         jnp.full((B,), t, jnp.int32))
        outs.append(lg)
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_train),
                               np.asarray(logits_dec), rtol=2e-4, atol=2e-4)


def test_sliding_window_ring_buffer_evicts():
    """With a cache smaller than the sequence, decode must still match the
    windowed training forward (ring eviction == window mask)."""
    cfg = dataclasses.replace(
        ARCHS["gemma2-27b"].reduced(seq_len_hint=S), dtype="float32",
        sliding_window=8)
    params = T.init_params(cfg, jax.random.key(0))
    tokens = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (B, S)))
    logits_train, _ = jax.jit(
        lambda p, b: T.forward(cfg, p, b))(params, {"tokens": tokens})
    caches = T.init_caches(cfg, B, S, dtype=jnp.float32)
    dec = jax.jit(lambda p, c, t, q: T.decode_step(cfg, p, c, t, q))
    outs = []
    for t in range(S):
        lg, caches = dec(params, caches, tokens[:, t],
                         jnp.full((B,), t, jnp.int32))
        outs.append(lg)
    np.testing.assert_allclose(np.asarray(logits_train),
                               np.asarray(jnp.stack(outs, 1)),
                               rtol=2e-4, atol=2e-4)
