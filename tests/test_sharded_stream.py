"""Streaming shards: the distributed ingest primitive.

Covers the ``ShardedDocStream`` partition contract (every document in
exactly ONE shard, for both partitioners — hypothesis property), shard
iteration vs the base stream, per-shard packing, the shard-assignment
refusals (engine construction and checkpoint resume), the ``WorkerIngest``
mid-batch capture→restore round-trip, trainer-level multi-worker mid-pass
save→load→resume bit-equality, and the UCI sidecar stats/index cache.
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.types import LDAConfig
from repro.data import (SHARD_PARTITIONERS, ShardedDocStream, UCIDocStream,
                        make_corpus, save_uci)
from repro.data.stream import CorpusDocStream, ListDocStream
from repro.dist import DIVIConfig, DIVIEngine, WorkerIngest
from repro.lda.trainer import DIVITrainer


def _docs(num_docs, rng):
    return [rng.integers(0, 50, size=rng.integers(1, 12))
            for _ in range(num_docs)]


# ---------------------------------------------------------------------------
# partition contract
# ---------------------------------------------------------------------------

@settings(max_examples=25)
@given(num_docs=st.integers(min_value=1, max_value=173),
       num_shards=st.integers(min_value=1, max_value=9),
       partitioner=st.sampled_from(SHARD_PARTITIONERS),
       seed=st.integers(min_value=0, max_value=5))
def test_every_doc_lands_in_exactly_one_shard(num_docs, num_shards,
                                              partitioner, seed):
    from hypothesis import assume
    assume(num_shards <= num_docs)
    stream = ListDocStream(_docs(num_docs, np.random.default_rng(num_docs)),
                           vocab_size=50)
    sharded = ShardedDocStream(stream, num_shards, partitioner=partitioner,
                               seed=seed)
    all_pos = np.concatenate([sharded.positions(w)
                              for w in range(num_shards)])
    # exactly one shard each: the union is a permutation of 0..D-1
    np.testing.assert_array_equal(np.sort(all_pos), np.arange(num_docs))
    # balanced to within one document, positions ascending per shard
    sizes = sharded.shard_sizes
    assert max(sizes) - min(sizes) <= 1
    for w in range(num_shards):
        pos = sharded.positions(w)
        assert (np.diff(pos) > 0).all()


def test_shard_iteration_matches_base_documents():
    rng = np.random.default_rng(1)
    docs = _docs(37, rng)
    stream = ListDocStream(docs, vocab_size=50)
    for partitioner in SHARD_PARTITIONERS:
        sharded = ShardedDocStream(stream, 3, partitioner=partitioner,
                                   seed=2)
        for w in range(3):
            sh = sharded.shard(w)
            got = list(sh.iter_from(0))
            assert len(got) == sh.num_docs
            for local, (ids, cnts) in enumerate(got):
                g = int(sharded.positions(w)[local])
                want_ids, want_cnts = np.unique(docs[g], return_counts=True)
                np.testing.assert_array_equal(np.sort(ids), want_ids)
                assert float(cnts.sum()) == len(docs[g])
            # mid-shard reopen: iter_from(k) == the tail of iter_from(0)
            tail = list(sh.iter_from(sh.num_docs // 2))
            for (a, ca), (b, cb) in zip(tail, got[sh.num_docs // 2:]):
                np.testing.assert_array_equal(a, b)
                np.testing.assert_array_equal(ca, cb)


def test_per_shard_csr_packing_covers_every_doc_once(tiny_corpus):
    """Each shard view drives its own packer — csr layout included: one
    pass through every shard emits every document of the corpus exactly
    once (flush included), with shard-local row stamps."""
    train, _, spec = tiny_corpus
    sharded = ShardedDocStream(CorpusDocStream(train), 3,
                               partitioner="hash", seed=4)
    for w in range(3):
        sh = sharded.shard(w)
        packer = sh.make_packer(8, layout="csr",
                                token_budget=8 * train.max_unique)
        seen = []
        for pos, (ids, cnts) in enumerate(sh.iter_from(0)):
            b = packer.add(pos, ids, cnts)
            if b is not None:
                seen.extend(int(r) for r in b.rows[b.rows >= 0])
        for b in packer.flush():
            seen.extend(int(r) for r in b.rows[b.rows >= 0])
        assert sorted(seen) == list(range(sh.num_docs))


# ---------------------------------------------------------------------------
# refusals: worker-count / assignment mismatches (satellite 2)
# ---------------------------------------------------------------------------

def test_sharded_stream_rejects_bad_shard_counts(tiny_corpus):
    train, _, _ = tiny_corpus
    stream = CorpusDocStream(train)
    with pytest.raises(ValueError, match="1 <= num_shards"):
        ShardedDocStream(stream, 0)
    with pytest.raises(ValueError, match="1 <= num_shards"):
        ShardedDocStream(stream, train.num_docs + 1)
    with pytest.raises(ValueError, match="unknown partitioner"):
        ShardedDocStream(stream, 2, partitioner="modulo")


def test_engine_rejects_shard_count_mismatch(tiny_corpus):
    train, _, spec = tiny_corpus
    cfg = LDAConfig(num_topics=8, vocab_size=spec.vocab_size,
                    estep_max_iters=20)
    sharded = ShardedDocStream(CorpusDocStream(train), 3)
    with pytest.raises(ValueError, match="3 shards .* 4 workers"):
        DIVIEngine(cfg, DIVIConfig(num_workers=4, batch_size=8), sharded)


def test_signature_refusals_name_the_mismatch(tiny_corpus):
    train, _, _ = tiny_corpus
    stream = CorpusDocStream(train)
    live = ShardedDocStream(stream, 4, partitioner="hash", seed=1)
    ok = live.signature()
    live.check_signature(dict(ok))     # identical assignment: accepted
    with pytest.raises(ValueError, match="num_workers=2"):
        live.check_signature({**ok, "num_shards": 2})
    with pytest.raises(ValueError, match="partitioner"):
        live.check_signature({**ok, "partitioner": "range"})
    with pytest.raises(ValueError, match="seed"):
        live.check_signature({**ok, "seed": 9})
    with pytest.raises(ValueError, match="num_docs"):
        live.check_signature({**ok, "num_docs": 7})


# ---------------------------------------------------------------------------
# ingest checkpointing
# ---------------------------------------------------------------------------

def test_worker_ingest_mid_batch_capture_restore_bit_equal(tiny_corpus):
    """Capture with a genuinely non-empty open packer (mid-batch), restore
    into a fresh ingest, and the batch sequences stay bit-identical."""
    train, _, _ = tiny_corpus
    sharded = ShardedDocStream(CorpusDocStream(train), 2,
                               partitioner="hash", seed=3)
    a = WorkerIngest(sharded.shard(0), 8)
    for _ in range(8 + 3):             # one emitted batch + 3 docs pending
        a.pull_doc()
    meta, arrays = a.capture()
    assert len(meta["pending_pos"]) == 3
    b = WorkerIngest(sharded.shard(0), 8)
    b.restore(meta, arrays)
    assert (b.cursor, b.passes, b.docs_pulled) == (11, 0, 11)
    for _ in range(6):                 # crosses the next emission AND the
        ba, bb = a.next_batch(), b.next_batch()     # 48-doc pass boundary
        np.testing.assert_array_equal(ba.token_ids, bb.token_ids)
        np.testing.assert_array_equal(ba.counts, bb.counts)
        np.testing.assert_array_equal(ba.rows, bb.rows)
    assert a.passes == b.passes == 1


@pytest.mark.parametrize("partitioner", SHARD_PARTITIONERS)
def test_divi_trainer_mid_pass_save_resume_bit_equal(partitioner,
                                                     tiny_corpus):
    """Multi-worker save→load→resume == uninterrupted run, bit for bit,
    with worker cursors genuinely mid-pass at the save point (48-doc
    shards, batch 7 — pass length is not a batch multiple)."""
    train, _, spec = tiny_corpus
    cfg = LDAConfig(num_topics=8, vocab_size=spec.vocab_size,
                    estep_max_iters=25)
    dcfg = DIVIConfig(num_workers=2, batch_size=7, staleness=2,
                      delay_prob=0.25, partitioner=partitioner,
                      partition_seed=11)

    a = DIVITrainer(cfg, dcfg, CorpusDocStream(train), seed=5)
    for _ in range(3):
        a.run_pass()
    meta, arrays = a.capture()
    assert any(0 < ing.cursor < ing.stream.num_docs
               for ing in a.eng.ingest)                  # genuinely mid-pass

    b = DIVITrainer(cfg, dcfg, CorpusDocStream(train), seed=5)
    b.restore(meta, arrays)
    for _ in range(3):
        a.run_pass()
        b.run_pass()
    assert a.docs_seen == b.docs_seen
    np.testing.assert_array_equal(np.asarray(a.state.lam),
                                  np.asarray(b.state.lam))
    np.testing.assert_array_equal(np.asarray(a.eng.shard.pi),
                                  np.asarray(b.eng.shard.pi))


def test_divi_restore_refuses_foreign_shard_assignment(tiny_corpus):
    train, _, spec = tiny_corpus
    cfg = LDAConfig(num_topics=8, vocab_size=spec.vocab_size,
                    estep_max_iters=20)
    mk = lambda dcfg: DIVITrainer(cfg, dcfg, CorpusDocStream(train), seed=0)
    src = mk(DIVIConfig(num_workers=2, batch_size=8))
    src.run_pass()
    meta, arrays = src.capture()
    with pytest.raises(ValueError, match="num_workers=2"):
        mk(DIVIConfig(num_workers=4, batch_size=8)).restore(meta, arrays)
    with pytest.raises(ValueError, match="partitioner"):
        mk(DIVIConfig(num_workers=2, batch_size=8,
                      partitioner="hash")).restore(meta, arrays)
    # a pre-streaming checkpoint (no shard assignment recorded) is refused
    legacy = {k: v for k, v in meta.items() if k != "sharding"}
    with pytest.raises(ValueError, match="predates streaming shards"):
        mk(DIVIConfig(num_workers=2, batch_size=8)).restore(legacy, arrays)


# ---------------------------------------------------------------------------
# UCI sidecar stats/index cache (satellite 1)
# ---------------------------------------------------------------------------

def _write_uci(tmp_path, seed=0):
    from repro.data import PAPER_CORPORA
    corpus = make_corpus(PAPER_CORPORA["tiny"], seed=seed)
    path = str(tmp_path / "docword.txt")
    save_uci(corpus, path)
    return path


def test_uci_sidecar_persists_and_serves_the_scan(tmp_path):
    path = _write_uci(tmp_path)
    s1 = UCIDocStream(path, index_every=10)
    words, maxu = s1.num_words, s1.max_unique
    assert os.path.exists(s1.index_path)

    # a second stream over the same file answers from the sidecar — no
    # rescan (the parser is disabled to prove it)
    s2 = UCIDocStream(path, index_every=10)
    s2._iter_docs = None               # any scan attempt would now blow up
    assert (s2.num_words, s2.max_unique) == (words, maxu)
    assert s2._index == s1._index and len(s2._index) > 1


def test_uci_sidecar_invalidated_on_file_change(tmp_path):
    path = _write_uci(tmp_path)
    words = UCIDocStream(path, index_every=10).num_words
    # rewrite the docword file (different corpus ⇒ different stats); bump
    # mtime past filesystem timestamp granularity
    _write_uci(tmp_path, seed=9)
    st = os.stat(path)
    os.utime(path, ns=(st.st_atime_ns, st.st_mtime_ns + 1_000_000_000))
    s2 = UCIDocStream(path, index_every=10)
    assert s2.num_words != words       # stale sidecar ignored, rescanned
    # knob changes invalidate too: a different index stride must rescan
    s3 = UCIDocStream(path, index_every=5)
    assert s3.num_words == s2.num_words
    assert len(s3._index) > len(s2._index)


def test_uci_sidecar_resume_matches_full_read(tmp_path):
    path = _write_uci(tmp_path)
    s = UCIDocStream(path, index_every=7)
    full = list(s.iter_from(0))
    # a fresh sidecar-served stream resumes mid-file through the index
    r = UCIDocStream(path, index_every=7)
    for cursor in (13, 40, 95):
        for (ids, cnts), (wids, wcnts) in zip(r.iter_from(cursor),
                                              full[cursor:]):
            np.testing.assert_array_equal(ids, wids)
            np.testing.assert_array_equal(cnts, wcnts)


def test_uci_opt_out_skips_sidecar(tmp_path):
    path = _write_uci(tmp_path)
    s = UCIDocStream(path, use_index_cache=False)
    s.num_words
    assert not os.path.exists(s.index_path)
