"""Ragged token pipeline (ISSUE 5): DocStream ingest, BatchPacker, serving.

The acceptance bars:

* **packer properties** — every document appears in exactly one emitted
  batch per pass, batch widths come off the one ladder and cover each
  document's live extent, no batch exceeds ``batch_size``;
* **stream-vs-materialized bit-equality** — an IVI run fed by a
  ``DocStream`` matches the padded-``Corpus`` run trajectory EXACTLY
  under the same batch schedule (λ, ⟨m_vk⟩, init_frac bit-equal), and a
  mid-epoch save → load → resume through the stream cursor continues
  bit-equally;
* **ragged-serving parity** — ``posterior_docs`` equals the padded
  ``posterior`` to fp32 tolerance (empty documents included, returned at
  the prior), and the double-buffered pipeline is bit-identical to the
  synchronous path;
* **UCI lazy stream** — ``UCIDocStream`` materializes to exactly what the
  eager ``load_uci`` produced, and resumes from a cursor.
"""
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import LDAConfig, LDAEngine
from repro.data import (PAPER_CORPORA, BatchPacker, CorpusDocStream,
                        ListDocStream, UCIDocStream, bucket_corpus,
                        bucket_padding_stats, corpus_from_docs, make_corpus,
                        materialize, save_uci, width_ladder)
from repro.lda import LDA


def _cfg(spec, **kw):
    kw.setdefault("estep_max_iters", 20)
    return LDAConfig(num_topics=4, vocab_size=spec.vocab_size, **kw)


def _same(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _ragged_docs(rng, n, vocab, max_len=40):
    out = []
    for _ in range(n):
        ln = int(rng.integers(0, max_len))
        ids = np.sort(rng.choice(vocab, size=ln, replace=False))
        cnts = (rng.poisson(1.0, ln) + 1).astype(np.float32)
        out.append((ids.astype(np.int32), cnts))
    return out


# ---------------------------------------------------------------------------
# packer properties
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), batch=st.integers(1, 32))
def test_packer_every_doc_exactly_once(seed, batch):
    rng = np.random.default_rng(seed)
    docs = _ragged_docs(rng, int(rng.integers(1, 80)), vocab=500)
    packer = BatchPacker(batch, max_width=64)
    ladder = width_ladder(64)
    emitted = []
    for pos, (ids, cnts) in enumerate(docs):
        out = packer.add(pos, ids, cnts)
        if out is not None:
            emitted.append(out)
    emitted += packer.flush()
    rows = np.concatenate([e.rows for e in emitted]) if emitted else []
    assert sorted(np.asarray(rows).tolist()) == list(range(len(docs)))
    for e in emitted:
        assert len(e.rows) <= batch
        assert e.width in ladder                     # widths off the ladder
        for r, pos in enumerate(e.rows):
            ids, cnts = docs[pos]
            assert len(ids) <= e.width               # width covers the doc
            _same(e.token_ids[r, : len(ids)], ids)   # content bit-equal
            _same(e.counts[r, : len(cnts)], cnts)
            assert not e.counts[r, len(cnts):].any()  # zero padding


def test_packer_open_ladder_extends_by_doubling():
    packer = BatchPacker(4)                          # serving: no max_width
    assert packer.width_for(512) == 512
    assert packer.width_for(513) == 1024
    assert packer.width_for(3000) == 4096
    assert packer.width_for(0) == 8                  # empty docs: first rung


def test_packer_clips_overlong_docs_to_most_frequent():
    packer = BatchPacker(1, max_width=4)
    ids = np.arange(8, dtype=np.int32)
    cnts = np.asarray([1, 9, 2, 8, 3, 7, 4, 6], np.float32)
    batch = packer.add(0, ids, cnts)
    assert batch.width == 4
    assert set(batch.counts[0].tolist()) == {9, 8, 7, 6}


def test_packer_pending_roundtrip():
    """pending_docs → load_pending reconstructs the exact packer state."""
    rng = np.random.default_rng(3)
    docs = _ragged_docs(rng, 23, vocab=300)
    a = BatchPacker(8, max_width=64)
    for pos, (ids, cnts) in enumerate(docs):
        a.add(pos, ids, cnts)
    b = BatchPacker(8, max_width=64)
    b.load_pending(a.pending_docs())
    fa, fb = a.flush(), b.flush()
    assert len(fa) == len(fb)
    for x, y in zip(fa, fb):
        assert x.width == y.width
        _same(x.rows, y.rows)
        _same(x.token_ids, y.token_ids)
        _same(x.counts, y.counts)


def test_bucket_corpus_delegates_to_one_policy(tiny_corpus):
    """Training buckets == the unified bucket_rows, and the padding stats
    carry per-bucket pad fractions."""
    train, _, _ = tiny_corpus
    from repro.data import bucket_rows
    buckets = bucket_corpus(train)
    raw = bucket_rows(train.counts)
    assert buckets.widths == [w for _, w in raw]
    for got, (rows, _) in zip(buckets.doc_idx, raw):
        _same(got, rows)
    stats = bucket_padding_stats(train, buckets)
    assert len(stats["per_bucket"]) == buckets.num_buckets
    assert all(0.0 <= b["pad_frac"] < 1.0 for b in stats["per_bucket"])


# ---------------------------------------------------------------------------
# stream-vs-materialized training bit-equality
# ---------------------------------------------------------------------------

def _packer_schedule(stream, batch_size):
    """The deterministic batch schedule the stream engine will run."""
    packer = BatchPacker(batch_size, max_width=stream.max_unique)
    out = []
    for pos, (ids, cnts) in enumerate(stream.iter_from(0)):
        b = packer.add(pos, ids, cnts)
        if b is not None:
            out.append(b)
    return out + packer.flush()


@pytest.mark.parametrize("algo,store", [("ivi", "dense"),
                                        ("ivi", "chunked"),
                                        ("sivi", "dense"),
                                        ("svi", "dense")])
def test_stream_run_bit_equals_padded_corpus_run(tiny_corpus, algo, store):
    """The tentpole invariant: per-minibatch ragged packing (no (D, L)
    corpus resident) is bit-transparent — the stream-fed trajectory equals
    the padded-corpus engine driven with the same batch schedule, over two
    full epochs, for every wire dtype."""
    train, _, spec = tiny_corpus
    cfg = _cfg(spec)
    stream = CorpusDocStream(train, spec.vocab_size)
    se = LDAEngine(cfg, stream, algo=algo, batch_size=16, seed=0,
                   memo_store=store, chunk_docs=32)
    ce = LDAEngine(cfg, train, algo=algo, batch_size=16, seed=0,
                   memo_store=store, chunk_docs=32)
    sched = _packer_schedule(stream, 16)
    for _ in range(2):
        se.run_epoch()
        for b in sched:
            ce.run_minibatch(b.rows, width=b.width)
    _same(se.state.lam, ce.state.lam)
    _same(se.state.m_vk, ce.state.m_vk)
    _same(se.state.init_frac, ce.state.init_frac)
    assert se.docs_seen == ce.docs_seen == 2 * train.num_docs
    if se.memo is not None:
        assert float(se.state.init_frac) == 0.0      # every doc visited
        sa, sb = se.memo.state_dict(), ce.memo.state_dict()
        for k in sa:
            _same(sa[k], sb[k])
        # and the streamed memoized bound equals the store read-through
        np.testing.assert_allclose(se.full_bound(), ce.full_bound(),
                                   rtol=1e-6)


@pytest.mark.parametrize("store", ["dense", "chunked"])
def test_stream_mid_epoch_save_resume_bit_equal(tiny_corpus, tmp_path,
                                                store):
    """Save with the cursor mid-epoch AND open packer buckets, resume on a
    fresh stream object: λ, ⟨m_vk⟩ and the memo must be bit-equal to the
    run that never stopped (the epoch cursor + open buckets round-trip
    through the manifest)."""
    train, _, spec = tiny_corpus
    cfg = _cfg(spec)
    path = os.path.join(tmp_path, "ck")
    kw = dict(algo="ivi", batch_size=16, seed=7, memo_store=store,
              chunk_docs=16)

    a = LDA(cfg, **kw).partial_fit(CorpusDocStream(train, spec.vocab_size),
                                   steps=3)
    cursor = a.trainer.stream_cursor
    assert cursor > 0                                # genuinely mid-epoch
    a.save(path)
    a.partial_fit(steps=6)                           # crosses the epoch tail

    b = LDA.load(path).resume(CorpusDocStream(train, spec.vocab_size))
    assert b.trainer.stream_cursor == cursor         # cursor round-tripped
    b.partial_fit(steps=6)

    _same(a.lam, b.lam)
    _same(a.state.m_vk, b.state.m_vk)
    _same(a.state.init_frac, b.state.init_frac)
    sa, sb = a.trainer.eng.memo.state_dict(), b.trainer.eng.memo.state_dict()
    for k in sa:
        _same(sa[k], sb[k])


def test_stream_facade_matches_engine(tiny_corpus):
    train, _, spec = tiny_corpus
    cfg = _cfg(spec)
    lda = LDA(cfg, algo="ivi", batch_size=16, seed=0).fit(
        CorpusDocStream(train, spec.vocab_size), epochs=2)
    eng = LDAEngine(cfg, CorpusDocStream(train, spec.vocab_size),
                    algo="ivi", batch_size=16, seed=0)
    eng.run_epoch()
    eng.run_epoch()
    _same(lda.lam, eng.state.lam)
    assert lda.docs_seen == eng.docs_seen


def test_stream_resume_mode_mismatch_refuses(tiny_corpus, tmp_path):
    """A stream-fed checkpoint cannot silently resume as a materialized
    run (and vice versa) — the epoch bookkeeping differs."""
    train, _, spec = tiny_corpus
    path = os.path.join(tmp_path, "ck")
    LDA(_cfg(spec), algo="ivi", batch_size=16).partial_fit(
        CorpusDocStream(train, spec.vocab_size), steps=1).save(path)
    with pytest.raises(ValueError, match="stream-fed"):
        LDA.load(path).resume(train)
    path2 = os.path.join(tmp_path, "ck2")
    LDA(_cfg(spec), algo="ivi", batch_size=16).partial_fit(
        train, steps=1).save(path2)
    with pytest.raises(ValueError, match="materialized"):
        LDA.load(path2).resume(CorpusDocStream(train, spec.vocab_size))


def test_stream_rejects_unsupported_modes(tiny_corpus):
    train, _, spec = tiny_corpus
    stream = CorpusDocStream(train, spec.vocab_size)
    with pytest.raises(ValueError, match="full-batch"):
        LDAEngine(_cfg(spec), stream, algo="mvi")
    with pytest.raises(ValueError, match="materialize"):
        LDAEngine(_cfg(spec), stream, algo="sivi", memo_store="gamma")
    from repro.data.stream import ShardedDocStream
    from repro.dist import DIVIConfig
    # D-IVI takes streams (docs/divi.md); what it refuses is a pre-dealt
    # ShardedDocStream whose shard count disagrees with the worker count.
    with pytest.raises(ValueError, match="shards"):
        LDA(_cfg(spec), algo="divi",
            distributed=DIVIConfig(num_workers=2)).fit(
            ShardedDocStream(stream, 3), rounds=1)


def test_plain_iterable_ingest(tiny_corpus):
    """LDA.fit on a raw list of token arrays: wrapped as a ListDocStream,
    bit-equal to the explicit stream."""
    _, _, spec = tiny_corpus
    rng = np.random.default_rng(5)
    raw = [rng.integers(0, spec.vocab_size, size=rng.integers(1, 25))
           for _ in range(40)]
    cfg = _cfg(spec)
    a = LDA(cfg, algo="ivi", batch_size=8, seed=1).fit(raw, epochs=1)
    b = LDA(cfg, algo="ivi", batch_size=8, seed=1).fit(
        ListDocStream(raw, spec.vocab_size), epochs=1)
    _same(a.lam, b.lam)


# ---------------------------------------------------------------------------
# UCI lazy stream
# ---------------------------------------------------------------------------

def test_uci_stream_matches_materialized_loader(tiny_corpus, tmp_path):
    from repro.data import load_uci
    train, _, _ = tiny_corpus
    path = os.path.join(tmp_path, "docword.txt.gz")
    save_uci(train, path)
    eager, _ = load_uci(path)
    stream = UCIDocStream(path)
    assert stream.num_docs == eager.num_docs
    assert stream.max_unique == eager.max_unique
    assert stream.num_words == float(np.asarray(eager.counts).sum())
    got = materialize(stream)
    _same(got.token_ids, eager.token_ids)
    _same(got.counts, eager.counts)


def test_uci_stream_cursor_resume(tiny_corpus, tmp_path):
    train, _, _ = tiny_corpus
    path = os.path.join(tmp_path, "docword.txt")
    save_uci(train, path)
    stream = UCIDocStream(path)
    full = list(stream.iter_from(0))
    tail = list(stream.iter_from(40))
    assert len(tail) == len(full) - 40
    for (ai, ac), (bi, bc) in zip(full[40:], tail):
        _same(ai, bi)
        _same(ac, bc)


def test_uci_stream_empty_doc_gaps(tmp_path):
    """docIDs absent from the file are empty docs: the stream mirrors the
    eager loader's placeholder and keeps positions aligned."""
    path = os.path.join(tmp_path, "docword.txt")
    with open(path, "w") as f:
        f.write("4\n9\n3\n")                   # doc 2 (1-based) is absent
        f.write("1 3 2\n3 5 1\n4 9 4\n")
    from repro.data import load_uci
    eager, _ = load_uci(path)
    stream = UCIDocStream(path)
    got = materialize(stream)
    assert stream.num_docs == 4
    _same(got.token_ids, eager.token_ids)
    _same(got.counts, eager.counts)


def test_uci_stream_fed_training_matches_materialized(tiny_corpus, tmp_path):
    """End-to-end: IVI fed by the lazy UCI stream == IVI on the eagerly
    loaded corpus driven with the same schedule."""
    train, _, spec = tiny_corpus
    cfg = _cfg(spec)
    path = os.path.join(tmp_path, "docword.txt.gz")
    save_uci(train, path)
    from repro.data import load_uci
    eager, _ = load_uci(path)
    stream = UCIDocStream(path)
    se = LDAEngine(cfg, stream, algo="ivi", batch_size=16, seed=0)
    se.run_epoch()
    ce = LDAEngine(cfg, eager, algo="ivi", batch_size=16, seed=0)
    for b in _packer_schedule(stream, 16):
        ce.run_minibatch(b.rows, width=b.width)
    _same(se.state.lam, ce.state.lam)
    _same(se.state.m_vk, ce.state.m_vk)


# ---------------------------------------------------------------------------
# ragged serving parity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served_lda(tiny_corpus):
    train, _, spec = tiny_corpus
    cfg = _cfg(spec, estep_max_iters=100, estep_tol=1e-6)
    return LDA(cfg, algo="ivi", batch_size=16, seed=0).fit(train, epochs=1)


def test_posterior_docs_matches_padded_posterior(served_lda, tiny_corpus):
    """Ragged requests == padded Corpus requests to fp32 tolerance, empty
    documents included (returned at the prior γ = α₀)."""
    _, _, spec = tiny_corpus
    lda = served_lda
    rng = np.random.default_rng(2)
    raw = [rng.integers(0, spec.vocab_size, size=rng.integers(1, 30))
           for _ in range(37)]
    raw[5] = np.asarray([], np.int64)            # an empty (OOV) request
    raw[20] = np.asarray([], np.int64)

    corpus = materialize(ListDocStream(raw, spec.vocab_size))
    inf = lda.inferencer(batch_size=8)
    g_pad = inf.posterior(corpus)
    g_ragged = inf.posterior_docs(raw, double_buffer=True)
    assert g_ragged.shape == g_pad.shape
    np.testing.assert_allclose(g_ragged, g_pad, rtol=2e-3, atol=2e-3)
    assert np.allclose(g_ragged[[5, 20]], lda.cfg.alpha0)


def test_posterior_docs_double_buffer_bit_equals_sync(served_lda, tiny_corpus):
    """Both paths run identical staged batches through the same jit
    entries — results must be bit-identical, in request order."""
    _, test, spec = tiny_corpus
    docs = list(CorpusDocStream(test, spec.vocab_size).iter_from(0))
    inf = served_lda.inferencer(batch_size=8)
    g_sync = inf.posterior_docs(docs, double_buffer=False)
    g_db = inf.posterior_docs(docs, double_buffer=True)
    _same(g_sync, g_db)
    assert g_sync.shape == (test.num_docs, served_lda.cfg.num_topics)


def test_posterior_docs_accepts_doc_stream(served_lda, tiny_corpus):
    _, test, spec = tiny_corpus
    stream = CorpusDocStream(test, spec.vocab_size)
    g = served_lda.posterior_docs(stream, batch_size=8)
    g_pad = served_lda.posterior(test, batch_size=8)
    np.testing.assert_allclose(g, g_pad, rtol=2e-3, atol=2e-3)


def test_posterior_docs_empty_request_set(served_lda):
    g = served_lda.posterior_docs([], batch_size=8)
    assert g.shape == (0, served_lda.cfg.num_topics)


def test_posterior_docs_producer_error_propagates(served_lda):
    def bad_docs():
        yield np.asarray([1, 2, 3])
        raise RuntimeError("ingest failure")

    with pytest.raises(RuntimeError, match="ingest failure"):
        served_lda.posterior_docs(bad_docs(), batch_size=8)


def test_posterior_docs_consumer_error_unblocks_producer(served_lda,
                                                         monkeypatch):
    """A consumer-side failure (the E-step dispatch raises) while the
    producer is blocked on the full bounded queue: the error must
    propagate and the packer thread must wind down, not stay blocked on
    q.put forever."""
    import threading
    import time

    inf = served_lda.inferencer(batch_size=4)
    boom = RuntimeError("device fell over")

    def bad_dispatch(staged):
        time.sleep(0.2)          # let the producer fill the queue + block
        raise boom

    monkeypatch.setattr(inf, "_dispatch", bad_dispatch)
    docs = [np.asarray([1, 2, 3])] * 64      # 16 batches ≫ queue capacity
    before = threading.active_count()
    with pytest.raises(RuntimeError, match="device fell over"):
        inf.posterior_docs(docs, double_buffer=True)
    for _ in range(100):                     # packer thread must wind down
        if threading.active_count() <= before:
            break
        time.sleep(0.05)
    assert threading.active_count() <= before


# ---------------------------------------------------------------------------
# review-fix regressions: OOV guards, unsorted UCI, iterable rebind
# ---------------------------------------------------------------------------

def test_stream_training_rejects_out_of_vocab_ids(tiny_corpus):
    """jnp gathers CLAMP out-of-range ids — the packer must refuse them
    instead of silently training on token V−1."""
    _, _, spec = tiny_corpus
    bad = [(np.asarray([0, spec.vocab_size + 7], np.int32),
            np.asarray([1.0, 2.0], np.float32))]
    lda = LDA(_cfg(spec), algo="ivi", batch_size=4)
    with pytest.raises(ValueError, match="outside the vocabulary"):
        lda.fit(bad, epochs=1)


def test_serving_rejects_out_of_vocab_ids(served_lda):
    with pytest.raises(ValueError, match="outside the vocabulary"):
        served_lda.posterior_docs([np.asarray([10**6])], batch_size=4)


def test_uci_stream_rejects_ungrouped_lines(tmp_path):
    """Lines out of docID order would silently misattribute tokens in a
    lazy reader — it must fail loudly instead."""
    path = os.path.join(tmp_path, "docword.txt")
    with open(path, "w") as f:
        f.write("2\n10\n3\n")
        f.write("1 5 2\n2 7 1\n1 9 1\n")    # doc 1 resumes after doc 2
    stream = UCIDocStream(path)
    with pytest.raises(ValueError, match="not grouped"):
        list(stream.iter_from(0))


def test_refit_same_plain_iterable_continues(tiny_corpus):
    """fit(docs); fit(docs) with the SAME list must continue training, not
    raise 'already bound' because of a fresh ListDocStream wrapper."""
    _, _, spec = tiny_corpus
    rng = np.random.default_rng(9)
    docs = [rng.integers(0, spec.vocab_size, size=rng.integers(1, 20))
            for _ in range(24)]
    cfg = _cfg(spec)
    lda = LDA(cfg, algo="ivi", batch_size=8, seed=2).fit(docs, epochs=1)
    lda.fit(docs, epochs=1)                  # continues the bound stream
    want = LDA(cfg, algo="ivi", batch_size=8, seed=2).fit(docs, epochs=2)
    _same(lda.lam, want.lam)
    with pytest.raises(ValueError, match="already bound"):
        lda.fit(list(docs), epochs=1)        # a DIFFERENT object still refuses
