"""Serving + distributed-bound correctness fixes (PR 4 satellites).

* empty documents (all-zero counts) through the serving buckets (now the
  unified ``repro.data.stream.bucket_rows``) / ``posterior`` /
  ``transform`` / the ``serve_lda`` launcher: routed to the smallest
  bucket, returned at the prior γ = α₀ / uniform θ̄ — never an all-zero
  row or a NaN from normalising one;
* ``TopicInferencer.cache_info`` reports batch counters and compiled
  widths as separate quantities;
* ``DIVITrainer.full_bound``: the all-gather-free per-shard reduction must
  match the single-host ``elbo_memoized_store`` oracle on the same state,
  and distributed ``evaluate()`` now reports ``elbo`` without a test
  corpus (through ``LDA.bound()`` too).
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bound import elbo_memoized_store
from repro.core.memo import DenseMemoStore
from repro.core.types import Corpus, LDAConfig
from repro.data.bow import corpus_from_docs
from repro.dist.protocol import DIVIConfig
from repro.data.stream import bucket_rows
from repro.lda import LDA
from repro.lda.infer import TopicInferencer
from repro.lda.trainer import DIVITrainer


def _inferencer(vocab=60, k=6, **kwargs):
    import jax
    cfg = LDAConfig(num_topics=k, vocab_size=vocab, estep_max_iters=30)
    lam = jax.random.gamma(jax.random.key(0), 100.0, (vocab, k)) * 0.01
    return cfg, TopicInferencer(cfg, lam, **kwargs)


# ---------------------------------------------------------------------------
# empty documents through the serving path
# ---------------------------------------------------------------------------

def test_serving_buckets_cover_every_document():
    """Every row — empty ones included — lands in exactly one bucket."""
    rng = np.random.default_rng(0)
    cnts = (rng.poisson(0.4, (50, 40)) * (rng.random((50, 40)) < 0.5))
    cnts = cnts.astype(np.float32)
    cnts[::7] = 0.0                            # sprinkle empty docs
    buckets = bucket_rows(cnts)
    covered = np.sort(np.concatenate([rows for rows, _ in buckets]))
    np.testing.assert_array_equal(covered, np.arange(50))
    # the empty docs ride the smallest bucket
    smallest_rows, smallest_w = buckets[0]
    assert smallest_w == 8
    assert set(np.nonzero(~(cnts > 0).any(1))[0]) <= set(smallest_rows)


def test_serving_buckets_all_empty_corpus():
    buckets = bucket_rows(np.zeros((5, 12), np.float32))
    assert len(buckets) == 1
    rows, w = buckets[0]
    np.testing.assert_array_equal(rows, np.arange(5))
    assert w == 8                               # smallest ladder rung


def test_posterior_empty_docs_return_prior(tiny_corpus):
    """Empty docs come back at γ = α₀ exactly; transform gives the uniform
    prior posterior — no all-zero γ row, no NaN θ̄."""
    cfg, inf = _inferencer(batch_size=8)
    rng = np.random.default_rng(1)
    ids = rng.integers(0, cfg.vocab_size, (11, 9)).astype(np.int32)
    cnts = (rng.poisson(1.0, (11, 9)) + 1).astype(np.float32)
    cnts[3] = 0.0                               # empty (OOV-only request)
    cnts[8] = 0.0
    corpus = Corpus(jnp.asarray(ids), jnp.asarray(cnts))
    gamma = inf.posterior(corpus)
    assert np.all(np.abs(gamma[[3, 8]] - cfg.alpha0) < 1e-6)
    assert not np.any(np.all(gamma == 0.0, axis=1))
    theta = inf.transform(corpus)
    assert np.all(np.isfinite(theta))
    np.testing.assert_allclose(theta[[3, 8]], 1.0 / cfg.num_topics,
                               rtol=1e-5)
    np.testing.assert_allclose(theta.sum(-1), 1.0, rtol=1e-5)


def test_transform_all_zero_corpus():
    """An entirely empty corpus transforms to the uniform prior posterior."""
    cfg, inf = _inferencer(batch_size=4)
    corpus = Corpus(jnp.zeros((6, 5), jnp.int32),
                    jnp.zeros((6, 5), jnp.float32))
    theta = inf.transform(corpus)
    assert np.all(np.isfinite(theta))
    np.testing.assert_allclose(theta, 1.0 / cfg.num_topics, rtol=1e-5)


def test_facade_transform_empty_docs(tiny_corpus):
    """The LDA facade path (what serve_lda drives) survives empty docs."""
    train, _, spec = tiny_corpus
    lda = LDA(num_topics=6, vocab_size=spec.vocab_size, algo="ivi",
              estep_max_iters=25, seed=0)
    lda.fit(train, epochs=1)
    ids = np.asarray(train.token_ids[:5])
    cnts = np.asarray(train.counts[:5]).copy()
    cnts[2] = 0.0
    theta = lda.transform(Corpus(jnp.asarray(ids), jnp.asarray(cnts)))
    assert np.all(np.isfinite(theta))
    np.testing.assert_allclose(theta[2], 1.0 / 6, rtol=1e-5)


# ---------------------------------------------------------------------------
# cache_info semantics
# ---------------------------------------------------------------------------

def test_cache_info_separates_batches_from_compilations():
    cfg, inf = _inferencer(batch_size=4)
    rng = np.random.default_rng(2)
    ids = rng.integers(0, cfg.vocab_size, (10, 20)).astype(np.int32)
    cnts = (rng.poisson(1.0, (10, 20)) + 1).astype(np.float32)
    cnts[:, 6:] = 0.0                           # all docs fit width 8
    corpus = Corpus(jnp.asarray(ids), jnp.asarray(cnts))
    inf.posterior(corpus)
    first = inf.cache_info()
    assert first["compiled_widths"] == [8]
    assert first["jit_entries"] == 1
    assert first["batches_per_width"] == {8: 3}    # 10 docs / batch 4
    inf.posterior(corpus)                          # same width, more batches
    second = inf.cache_info()
    assert second["compiled_widths"] == [8]        # no new compilation
    assert second["jit_entries"] == 1
    assert second["batches_per_width"] == {8: 6}   # counters, not jit entries


def test_serve_lda_latency_report(tmp_path, monkeypatch, capsys):
    """The launcher end-to-end on the tiny corpus: its jit-cache line and
    JSON record must use the corrected cache_info fields."""
    import sys
    from repro.launch import serve_lda
    out = tmp_path / "serve.jsonl"
    monkeypatch.setattr(sys, "argv", [
        "serve_lda", "--corpus", "tiny", "--requests", "3", "--batch", "8",
        "--topics", "6", "--estep-iters", "20", "--warm-epochs", "1",
        "--out", str(out)])
    serve_lda.main()
    text = capsys.readouterr().out
    assert "compiled widths" in text
    rec = json.loads(out.read_text().strip().splitlines()[-1])
    assert rec["ok"] and rec["jit_widths"]
    assert set(map(int, rec["batches_per_width"]))  == set(rec["jit_widths"])


# ---------------------------------------------------------------------------
# D-IVI memoized bound
# ---------------------------------------------------------------------------

@pytest.fixture
def divi_trainer():
    rng = np.random.default_rng(3)
    docs = [rng.integers(0, 120, size=rng.integers(5, 30))
            for _ in range(41)]                 # 41 % 4: ragged shard sizes
    corpus = corpus_from_docs(docs, 120)
    cfg = LDAConfig(num_topics=6, vocab_size=120, estep_max_iters=30)
    dcfg = DIVIConfig(num_workers=4, batch_size=5, staleness=2)
    return DIVITrainer(cfg, dcfg, corpus, seed=0), corpus


def test_divi_full_bound_matches_single_host_oracle(divi_trainer):
    """Per-shard stream read-through == elbo_memoized_store on the
    flattened state. The flat oracle permutes the corpus into shard order
    (shard w's documents are the corpus rows at ``positions(w)``) and
    stacks the live memo rows of each shard — the trailing phantom row of
    the ``max(shard sizes)``-padded memo is excluded. ALL 41 documents are
    covered: streaming shards drop no ``D % P`` tail."""
    tr, corpus = divi_trainer
    for _ in range(3):
        tr.run_pass()
    got = tr.full_bound()
    eng = tr.eng
    sh = eng.shard
    order = np.concatenate([eng.sharded.positions(w) for w in range(4)])
    assert len(order) == 41
    flat = Corpus(corpus.token_ids[order], corpus.counts[order])
    sizes = eng.sharded.shard_sizes
    store = DenseMemoStore(
        pi=jnp.concatenate([sh.pi[w][:sizes[w]] for w in range(4)]),
        visited=jnp.concatenate([sh.visited[w][:sizes[w]]
                                 for w in range(4)]))
    want = float(elbo_memoized_store(tr.cfg, flat, store, tr.eng.state.lam))
    np.testing.assert_allclose(got, want, rtol=1e-5)
    assert np.isfinite(got)


def test_divi_evaluate_reports_elbo_without_test_corpus(divi_trainer):
    tr, _ = divi_trainer
    tr.run_pass()
    out = tr.evaluate()
    assert "elbo" in out and np.isfinite(out["elbo"])
    assert tr.history.elbo == [out["elbo"]]
    # D-IVI folds corrections into a Robbins–Monro average under parameter
    # lag, so round-to-round monotonicity is NOT guaranteed (unlike exact
    # IVI) — only that the bound stays finite and the history accumulates
    tr.run_pass()
    out2 = tr.evaluate()
    assert np.isfinite(out2["elbo"])
    assert len(tr.history.elbo) == 2


def test_facade_bound_distributed():
    """LDA.bound() no longer raises for distributed runs."""
    rng = np.random.default_rng(4)
    docs = [rng.integers(0, 80, size=rng.integers(5, 20))
            for _ in range(24)]
    corpus = corpus_from_docs(docs, 80)
    lda = LDA(num_topics=5, vocab_size=80, algo="divi",
              distributed=DIVIConfig(num_workers=2, batch_size=4),
              estep_max_iters=25, seed=0)
    lda.fit(corpus, rounds=2)
    assert np.isfinite(lda.bound())
    assert "elbo" in lda.evaluate()
