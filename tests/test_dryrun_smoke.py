"""End-to-end guard for the dry-run launcher (deliverable e).

Runs one real (arch × shape × mesh) pair in a subprocess with the forced
512-device environment and checks the JSON record: compile success, memory
analysis present, roofline terms positive. The full 80-pair sweep lives in
results/dryrun.jsonl (regenerated via ``python -m repro.launch.dryrun
--all``); this test keeps the machinery honest in CI.
"""
import json
import os
import subprocess
import sys

import pytest


def _run_pair(tmp_path, arch, shape, extra=()):
    out = os.path.join(tmp_path, "dry.jsonl")
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", "single", "--out", out, *extra]
    res = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=900)
    assert res.returncode == 0, res.stderr[-2000:]
    rec = json.loads(open(out).readlines()[-1])
    return rec


def test_dryrun_decode_pair(tmp_path):
    rec = _run_pair(tmp_path, "qwen2.5-3b", "decode_32k")
    assert rec["ok"], rec.get("error")
    assert rec["chips"] == 256
    assert rec["memory"]["temp_gb"] > 0
    rf = rec["roofline"]
    assert rf["compute_s"] > 0 and rf["collective_s"] > 0
    assert rec["hlo"]["dot_flops"] > 1e8


def test_dryrun_respects_levers(tmp_path):
    rec = _run_pair(tmp_path, "xlstm-1.3b", "train_4k",
                    extra=("--profile", "fsdp_only"))
    assert rec["ok"], rec.get("error")
    assert rec["profile"] == "fsdp_only"
    # the custom-VJP + fsdp_only configuration must fit HBM (§Perf)
    assert rec["memory"]["temp_gb"] < 16.0


def test_sweep_results_are_complete():
    """The shipped results files cover all 80 pairs, all OK."""
    path = "results/dryrun.jsonl"
    if not os.path.exists(path):
        pytest.skip("sweep results not present")
    seen = {}
    for line in open(path):
        r = json.loads(line)
        mesh = r["mesh"] if isinstance(r["mesh"], str) else (
            "multi" if r["chips"] == 512 else "single")
        if not r.get("seq_shard") and r.get("profile", "tp_fsdp") == "tp_fsdp" \
                and r.get("microbatches", 1) == 1:
            seen[(r["arch"], r["shape"], mesh)] = r.get("ok", False)
    assert len(seen) >= 80, len(seen)
    assert all(seen.values())
