"""Shared fixtures. NOTE: tests run on the single host CPU device —
XLA_FLAGS device-count forcing is reserved for launch/dryrun.py and the
subprocess-based distribution tests."""
import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def tiny_corpus():
    from repro.data import PAPER_CORPORA, make_corpus
    spec = PAPER_CORPORA["tiny"]
    return (make_corpus(spec, split="train", seed=0),
            make_corpus(spec, split="test", seed=0), spec)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
