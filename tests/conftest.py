"""Shared fixtures. NOTE: tests run on the single host CPU device —
XLA_FLAGS device-count forcing is reserved for launch/dryrun.py and the
subprocess-based distribution tests."""
import functools
import inspect
import sys
import types
import zlib

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


def _install_hypothesis_fallback():
    """Deterministic stand-in for the hypothesis surface this suite uses.

    Without the real package, ``from hypothesis import given, ...`` used to
    *error five test modules out of collection*. This fallback runs each
    property test on a fixed number of seeded random examples instead —
    collection always succeeds, and installing the real dependency
    (``pip install -e .[test]``) transparently restores full
    shrinking/replay behaviour. Only the strategies the suite draws from
    are provided: integers / booleans / sampled_from.
    """
    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _UnsatisfiedAssumption(Exception):
        """Raised by assume(False): discard the example, like hypothesis."""

    def assume(condition):
        if not condition:
            raise _UnsatisfiedAssumption
        return True

    st = types.ModuleType("hypothesis.strategies")
    st.integers = lambda min_value=0, max_value=2**31 - 1: _Strategy(
        lambda r: int(r.integers(min_value, max_value + 1)))
    st.booleans = lambda: _Strategy(lambda r: bool(r.integers(0, 2)))
    st.sampled_from = lambda seq: _Strategy(
        lambda r, _s=tuple(seq): _s[int(r.integers(0, len(_s)))])

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def run(*args, **kwargs):
                n = getattr(run, "_max_examples", 10)
                rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
                runs = 0
                for _ in range(n * 20):       # bounded redraws for assume()
                    if runs == n:
                        break
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    try:
                        fn(*args, **kwargs, **drawn)
                        runs += 1
                    except _UnsatisfiedAssumption:
                        continue
                if runs == 0:
                    pytest.skip("hypothesis fallback: no example satisfied "
                                "assume()")
            # drawn params are not fixtures: hide them from pytest
            run.__signature__ = inspect.Signature(parameters=[])
            return run
        return deco

    def settings(max_examples=10, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    mod = types.ModuleType("hypothesis")
    mod.given, mod.settings, mod.strategies = given, settings, st
    mod.assume = assume
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


try:
    import hypothesis  # noqa: F401  (the real one, if installed)
except ImportError:
    _install_hypothesis_fallback()


@pytest.fixture(scope="session")
def tiny_corpus():
    from repro.data import PAPER_CORPORA, make_corpus
    spec = PAPER_CORPORA["tiny"]
    return (make_corpus(spec, split="train", seed=0),
            make_corpus(spec, split="test", seed=0), spec)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
