"""Data pipeline: BOW construction, synthetic corpus statistics."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import PAPER_CORPORA, corpus_from_docs, make_corpus, \
    pad_corpus


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_corpus_from_docs_preserves_counts(seed):
    rng = np.random.default_rng(seed)
    docs = [rng.integers(0, 50, size=rng.integers(1, 30))
            for _ in range(12)]
    corpus = corpus_from_docs(docs, 50)
    for i, doc in enumerate(docs):
        want = np.bincount(doc, minlength=50).astype(np.float32)
        got = np.zeros(50, np.float32)
        ids = np.asarray(corpus.token_ids[i])
        cnt = np.asarray(corpus.counts[i])
        np.add.at(got, ids, cnt)
        np.testing.assert_array_equal(got, want)


def test_unique_token_layout():
    corpus = corpus_from_docs([np.array([3, 3, 3, 7])], 10)
    ids = np.asarray(corpus.token_ids[0])
    cnt = np.asarray(corpus.counts[0])
    live = cnt > 0
    assert len(np.unique(ids[live])) == live.sum()   # no duplicate slots
    assert cnt.sum() == 4


def test_pad_corpus():
    corpus = corpus_from_docs([np.array([1, 2]), np.array([3])], 10)
    padded = pad_corpus(corpus, 5)
    assert padded.num_docs == 5
    assert float(padded.counts[2:].sum()) == 0.0
    assert float(padded.num_words) == float(corpus.num_words)


def test_synthetic_matches_table1_statistics():
    spec = PAPER_CORPORA["ap"]
    corpus = make_corpus(spec, split="train", seed=0, scale=0.2)
    lens = np.asarray(corpus.counts.sum(-1))
    # mean length within 15% of the paper's Table 1
    assert abs(lens.mean() - spec.mean_len) / spec.mean_len < 0.15
    assert int(np.asarray(corpus.token_ids).max()) < spec.vocab_size


def test_train_test_share_topics():
    """Same ground-truth φ generates both splits → a model trained on train
    must transfer to test (checked indirectly: vocab overlap is high)."""
    spec = PAPER_CORPORA["tiny"]
    tr = make_corpus(spec, split="train", seed=0)
    te = make_corpus(spec, split="test", seed=0)
    vtr = set(np.asarray(tr.token_ids)[np.asarray(tr.counts) > 0].tolist())
    vte = set(np.asarray(te.token_ids)[np.asarray(te.counts) > 0].tolist())
    inter = len(vtr & vte) / max(len(vte), 1)
    assert inter > 0.6, inter


def test_uci_roundtrip(tmp_path):
    """save_uci → load_uci reproduces the corpus counts exactly."""
    import os
    from repro.data import load_uci, save_uci
    spec = PAPER_CORPORA["tiny"]
    corpus = make_corpus(spec, split="train", seed=0)
    path = os.path.join(tmp_path, "docword.txt.gz")
    save_uci(corpus, path)
    loaded, vocab = load_uci(path)
    a = np.zeros((corpus.num_docs, spec.vocab_size))
    b = np.zeros_like(a)
    for c, out in ((corpus, a), (loaded, b)):
        ids, cnt = np.asarray(c.token_ids), np.asarray(c.counts)
        for d in range(ids.shape[0]):
            np.add.at(out[d], ids[d], cnt[d])
    np.testing.assert_array_equal(a, b)


def test_uci_max_docs(tmp_path):
    import os
    from repro.data import load_uci, save_uci
    spec = PAPER_CORPORA["tiny"]
    corpus = make_corpus(spec, split="train", seed=0)
    path = os.path.join(tmp_path, "docword.txt")
    save_uci(corpus, path)
    loaded, _ = load_uci(path, max_docs=10)
    assert loaded.num_docs == 10
