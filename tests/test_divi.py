"""D-IVI distribution semantics.

Single-device tests use the vmap worker simulation; the production
shard_map path is validated in a subprocess with 8 forced host devices
(bit-exact agreement with the simulation is the acceptance criterion).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LDAConfig, log_predictive, split_heldout
from repro.data import PAPER_CORPORA, make_corpus
from repro.dist import DIVIConfig, DIVIEngine


def _data():
    spec = PAPER_CORPORA["tiny"]
    return (make_corpus(spec, split="train", seed=0),
            make_corpus(spec, split="test", seed=0), spec)


def test_divi_single_worker_matches_sivi_quality():
    train, test, spec = _data()
    cfg = LDAConfig(num_topics=8, vocab_size=spec.vocab_size,
                    estep_max_iters=40)
    obs, held = split_heldout(test)
    eng = DIVIEngine(cfg, DIVIConfig(num_workers=1, batch_size=16), train,
                     seed=0)
    for _ in range(12):
        eng.run_round()
    lpp = float(log_predictive(cfg, eng.lam, obs, held))
    assert np.isfinite(lpp) and lpp > -4.0


@pytest.mark.parametrize("workers", [2, 4])
def test_divi_quality_stable_across_P(workers):
    """Table 2: LPP is essentially flat in the number of processors."""
    train, test, spec = _data()
    cfg = LDAConfig(num_topics=8, vocab_size=spec.vocab_size,
                    estep_max_iters=40)
    obs, held = split_heldout(test)
    ref_eng = DIVIEngine(cfg, DIVIConfig(num_workers=1, batch_size=16),
                         train, seed=0)
    par_eng = DIVIEngine(cfg, DIVIConfig(num_workers=workers, batch_size=16),
                         train, seed=0)
    rounds = 16
    for _ in range(rounds):
        ref_eng.run_round()
    for _ in range(rounds // workers):
        par_eng.run_round()
    ref_lpp = float(log_predictive(cfg, ref_eng.lam, obs, held))
    par_lpp = float(log_predictive(cfg, par_eng.lam, obs, held))
    assert abs(ref_lpp - par_lpp) < 0.35, (ref_lpp, par_lpp)


def test_divi_delay_robustness():
    """Fig. 5: convergence persists under dropped/delayed workers."""
    train, test, spec = _data()
    cfg = LDAConfig(num_topics=8, vocab_size=spec.vocab_size,
                    estep_max_iters=40)
    obs, held = split_heldout(test)
    eng = DIVIEngine(cfg, DIVIConfig(num_workers=4, batch_size=16,
                                     delay_prob=0.5), train, seed=0)
    first = float(log_predictive(cfg, eng.lam, obs, held))
    for _ in range(16):
        eng.run_round()
    last = float(log_predictive(cfg, eng.lam, obs, held))
    assert last > first + 0.2


def test_divi_staleness_still_converges():
    train, test, spec = _data()
    cfg = LDAConfig(num_topics=8, vocab_size=spec.vocab_size,
                    estep_max_iters=40)
    obs, held = split_heldout(test)
    eng = DIVIEngine(cfg, DIVIConfig(num_workers=2, batch_size=16,
                                     staleness=3), train, seed=0)
    first = float(log_predictive(cfg, eng.lam, obs, held))
    for _ in range(6):
        eng.run_round()
    last = float(log_predictive(cfg, eng.lam, obs, held))
    assert last > first + 0.2


@pytest.mark.parametrize("partitioner", ["range", "hash"])
def test_divi_stream_fed_bit_equals_materialized(partitioner):
    """The acceptance oracle of the streaming refactor: a D-IVI engine fed
    a lazy ``DocStream`` is BIT-equal to one fed the materialized corpus,
    round for round, under the identical drop schedule — for both
    partitioners."""
    from repro.data.stream import CorpusDocStream

    train, _, spec = _data()
    cfg = LDAConfig(num_topics=8, vocab_size=spec.vocab_size,
                    estep_max_iters=40)
    dcfg = DIVIConfig(num_workers=4, batch_size=8, staleness=2,
                      delay_prob=0.3, partitioner=partitioner,
                      partition_seed=5)
    e1 = DIVIEngine(cfg, dcfg, train, seed=3)
    e2 = DIVIEngine(cfg, dcfg, CorpusDocStream(train), seed=3)
    for _ in range(4):
        e1.run_round()
        e2.run_round()
    assert e1.docs_seen == e2.docs_seen
    np.testing.assert_array_equal(np.asarray(e1.lam), np.asarray(e2.lam))
    np.testing.assert_array_equal(np.asarray(e1.shard.pi),
                                  np.asarray(e2.shard.pi))
    np.testing.assert_array_equal(np.asarray(e1.shard.visited),
                                  np.asarray(e2.shard.visited))


_SHARDMAP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, numpy as np
    from repro.core import LDAConfig
    from repro.dist import DIVIEngine, DIVIConfig
    from repro.data import PAPER_CORPORA, make_corpus
    from repro.data.stream import CorpusDocStream

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    spec = PAPER_CORPORA["tiny"]
    train = make_corpus(spec, split="train", seed=0)
    cfg = LDAConfig(num_topics=8, vocab_size=250, estep_max_iters=40)
    dcfg = DIVIConfig(num_workers=4, batch_size=16)
    e1 = DIVIEngine(cfg, dcfg, train, seed=0, mesh=mesh)
    e2 = DIVIEngine(cfg, dcfg, train, seed=0)
    e3 = DIVIEngine(cfg, dcfg, CorpusDocStream(train), seed=0, mesh=mesh)
    for _ in range(5):
        e1.run_round(); e2.run_round(); e3.run_round()
    diff = float(np.abs(np.asarray(e1.lam) - np.asarray(e2.lam)).max())
    stream_equal = bool(np.array_equal(np.asarray(e1.lam),
                                       np.asarray(e3.lam)))
    print(json.dumps({"diff": diff, "stream_equal": stream_equal}))
""")


def test_divi_shard_map_matches_vmap_subprocess():
    """shard_map ≈ vmap (fp reduction-order tolerance), and on the mesh
    path stream-fed == corpus-fed exactly."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _SHARDMAP_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    # psum vs sum reduce in different orders: fp32 noise only, never drift
    assert res["diff"] < 5e-4, res
    assert res["stream_equal"], res
