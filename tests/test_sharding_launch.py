"""Sharding rules + launch machinery (no real multi-device needed:
AbstractMesh provides shape/axis metadata for the spec rules; the actual
512-device lowering is covered by launch/dryrun.py runs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_abstract_mesh
from repro.models import transformer as T
from repro.sharding import cache_specs, fsdp_axes, param_specs

MESH = make_abstract_mesh((16, 16), ("data", "model"))
MESH3 = make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _check_divisibility(shapes, specs, mesh):
    flat_s = jax.tree.leaves(shapes,
                             is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    flat_p, _ = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p)
    for s, spec in zip(flat_s, flat_p):
        assert len(spec) <= len(s.shape), (s.shape, spec)
        for dim, axes in zip(s.shape, spec):
            if axes is None:
                continue
            axes = (axes,) if isinstance(axes, str) else axes
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            assert dim % size == 0, (s.shape, spec)


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("mesh", [MESH, MESH3], ids=["single", "multi"])
def test_param_specs_divisible(arch, mesh):
    cfg = ARCHS[arch]
    shapes = jax.eval_shape(lambda: T.init_params(cfg, jax.random.key(0)))
    specs = param_specs(mesh, shapes)
    _check_divisibility(shapes, specs, mesh)


@pytest.mark.parametrize("arch", ["gemma2-27b", "musicgen-medium",
                                  "zamba2-1.2b", "xlstm-1.3b"])
def test_cache_specs_divisible(arch):
    cfg = ARCHS[arch]
    shapes = jax.eval_shape(lambda: T.init_caches(cfg, 128, 4096))
    specs = cache_specs(MESH, cfg, shapes)
    _check_divisibility(shapes, specs, MESH)


def test_param_specs_shard_big_dims():
    """The FFN hidden of yi-9b must actually be model-sharded."""
    cfg = ARCHS["yi-9b"]
    shapes = jax.eval_shape(lambda: T.init_params(cfg, jax.random.key(0)))
    specs = param_specs(MESH, shapes)
    mlp_spec = specs["stages"][0][0]["mlp"]["w_up"]
    assert "model" in tuple(mlp_spec)


def test_fsdp_axes():
    assert fsdp_axes(MESH) == ("data",)
    assert fsdp_axes(MESH3) == ("pod", "data")


# ---------------------------------------------------------------------------
# HLO analysis machinery
# ---------------------------------------------------------------------------

def test_hlo_trip_count_multiplication():
    """A scan of length 10 must multiply body dot flops by 10."""
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((32, 64), jnp.float32),
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    res = H.analyze(comp.as_text())
    want = 10 * 2 * 32 * 64 * 64
    assert abs(res["dot_flops"] - want) / want < 0.05, res["dot_flops"]


def test_hlo_nested_scan_multiplies():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=4)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((8, 16), jnp.float32),
        jax.ShapeDtypeStruct((16, 16), jnp.float32)).compile()
    res = H.analyze(comp.as_text())
    want = 12 * 2 * 8 * 16 * 16
    assert abs(res["dot_flops"] - want) / want < 0.05, res["dot_flops"]


def test_hlo_shape_bytes():
    assert H._shape_bytes("f32[8,128]{1,0}") == 8 * 128 * 4
    assert H._shape_bytes("bf16[2,3]") == 12
    assert H._shape_bytes("(f32[4], s32[2])") == 24


def test_input_specs_cover_all_shapes():
    import os
    # avoid initializing the 512-device runtime here: only spec shapes
    from repro.configs import get_shape
    from repro.configs.base import INPUT_SHAPES
    for name in INPUT_SHAPES:
        s = get_shape(name)
        assert s.kind in ("train", "prefill", "decode")
