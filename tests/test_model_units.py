"""Unit tests for substrate pieces: attention masking, recurrence core,
MoE dispatch, pattern segmentation, norms/rope."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ARCHS
from repro.configs.base import (ATTN, ATTN_LOCAL, MAMBA2, MAMBA2_SHARED,
                                MLSTM, MOE, SLSTM, ModelConfig)
from repro.models import attention as A
from repro.models import moe as M
from repro.models import recurrent as R
from repro.models.layers import rope
from repro.models.transformer import segment_pattern


def _attn_cfg(**kw):
    base = dict(name="t", family="dense", num_layers=1, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
                attn_chunk=8, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def _naive_attention(cfg, p, x, window=None):
    """O(S²) reference without chunking."""
    b, s, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q, k, v = A._qkv(cfg, p, x)
    pos = jnp.arange(s)
    if cfg.use_rope:
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    q = q * A._scale(cfg)
    qg = q.reshape(b, s, kv, h // kv, hd)
    logits = jnp.einsum("bqgrk,bsgk->bgrqs", qg, k).astype(jnp.float32)
    from repro.models.layers import softcap
    logits = softcap(logits, cfg.attn_logit_softcap)
    mask = pos[:, None] >= pos[None, :]
    if window is not None:
        mask &= pos[:, None] - pos[None, :] < window
    logits = jnp.where(mask, logits, A.NEG_INF)
    w = jax.nn.softmax(logits, -1)
    out = jnp.einsum("bgrqs,bsgk->bqgrk", w.astype(v.dtype), v)
    return jnp.einsum("bthk,hkd->btd", out.reshape(b, s, h, hd), p["wo"])


@pytest.mark.parametrize("window", [None, 5])
@pytest.mark.parametrize("s", [16, 24])   # 24: not divisible by chunk 8? yes it is; use 20
def test_chunked_attention_matches_naive(window, s, rng):
    cfg = _attn_cfg()
    p = A.attn_init(cfg, jax.random.key(0))
    x = jnp.asarray(rng.normal(0, 1, (2, s, 64)).astype(np.float32))
    got = A.attention_train(cfg, p, x, window=window)
    want = _naive_attention(cfg, p, x, window=window)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_chunked_attention_padding_path(rng):
    """Sequence not divisible by the q-chunk (VLM prefix case)."""
    cfg = _attn_cfg(attn_chunk=8)
    p = A.attn_init(cfg, jax.random.key(0))
    x = jnp.asarray(rng.normal(0, 1, (2, 19, 64)).astype(np.float32))
    got = A.attention_train(cfg, p, x)
    want = _naive_attention(cfg, p, x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_attention_softcap_and_qknorm(rng):
    cfg = _attn_cfg(attn_logit_softcap=30.0, qk_norm=True)
    p = A.attn_init(cfg, jax.random.key(0))
    x = jnp.asarray(rng.normal(0, 1, (2, 16, 64)).astype(np.float32))
    got = A.attention_train(cfg, p, x)
    want = _naive_attention(cfg, p, x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# chunked recurrence core
# ---------------------------------------------------------------------------

def _naive_recurrence(q, k, v, log_a, log_i, stabilize):
    """Step-by-step reference using recurrence_step."""
    b, t, h, n = q.shape
    p = v.shape[-1]
    state = R.init_state(b, h, n, p)
    ys = []
    for i in range(t):
        li = log_i[:, i] if log_i is not None else None
        y, state = R.recurrence_step(q[:, i], k[:, i], v[:, i],
                                     log_a[:, i], li, state, stabilize)
        ys.append(y)
    return jnp.stack(ys, 1), state


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), stab=st.booleans(),
       chunk=st.sampled_from([4, 8, 16]))
def test_chunked_scan_matches_stepwise(seed, stab, chunk):
    rng = np.random.default_rng(seed)
    b, t, h, n, p = 2, 16, 3, 5, 4
    q = jnp.asarray(rng.normal(0, 1, (b, t, h, n)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (b, t, h, n)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (b, t, h, p)).astype(np.float32))
    la = jnp.asarray(-np.abs(rng.normal(0.5, 0.5, (b, t, h))).astype(np.float32))
    li = jnp.asarray(rng.normal(0, 1, (b, t, h)).astype(np.float32)) if stab \
        else None
    y1, s1 = R.chunked_scan(q, k, v, la, li, R.init_state(b, h, n, p),
                            chunk, stabilize=stab)
    y2, s2 = _naive_recurrence(q, k, v, la, li, stab)
    np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s1.c, s2.c, rtol=2e-4, atol=2e-4)


def test_conv1d_train_step_agree(rng):
    x = jnp.asarray(rng.normal(0, 1, (2, 10, 6)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 1, (4, 6)).astype(np.float32))
    b = jnp.asarray(rng.normal(0, 1, (6,)).astype(np.float32))
    full = R.conv1d_train(x, w, b)
    state = jnp.zeros((2, 3, 6))
    outs = []
    for t in range(10):
        y, state = R.conv1d_step(x[:, t], state, w, b)
        outs.append(y)
    np.testing.assert_allclose(full, jnp.stack(outs, 1), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# MoE dispatch
# ---------------------------------------------------------------------------

def _dense_moe_reference(cfg, p, x):
    """All-experts dense reference."""
    logits = (x @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    top_p, top_i = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    if cfg.norm_topk_prob:
        top_p = top_p / top_p.sum(-1, keepdims=True)
    h = jax.nn.silu(jnp.einsum("nd,edf->nef", x, p["w_gate"])) \
        * jnp.einsum("nd,edf->nef", x, p["w_up"])
    out_all = jnp.einsum("nef,efd->ned", h, p["w_down"])
    y = jnp.zeros_like(x)
    for j in range(cfg.num_experts_per_tok):
        y = y + top_p[:, j:j+1] * jnp.take_along_axis(
            out_all, top_i[:, j][:, None, None], axis=1)[:, 0]
    if cfg.num_shared_experts:
        sp = p["shared"]
        y = y + (jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])) \
            @ sp["w_down"]
    return y


@pytest.mark.parametrize("shared", [0, 1])
def test_moe_ragged_matches_dense(shared, rng):
    cfg = ModelConfig(name="m", family="moe", num_layers=1, d_model=32,
                      num_heads=2, num_kv_heads=2, d_ff=0, vocab_size=11,
                      num_experts=4, num_experts_per_tok=2, moe_d_ff=16,
                      num_shared_experts=shared, moe_capacity_factor=4.0,
                      dtype="float32")
    p = M.moe_init(cfg, jax.random.key(0))
    x = jnp.asarray(rng.normal(0, 0.5, (24, 32)).astype(np.float32))
    got, aux = M.moe_ffn_local(cfg, p, x, jnp.asarray(0), 1)
    want = _dense_moe_reference(cfg, p, x)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)
    assert float(aux["counts"].sum()) == 24 * 2
    assert float(aux["dropped"]) == 0.0


def test_moe_rank_partition_sums_to_full(rng):
    """Σ over simulated model ranks of partial outputs == single-rank out."""
    cfg = ModelConfig(name="m", family="moe", num_layers=1, d_model=16,
                      num_heads=2, num_kv_heads=2, d_ff=0, vocab_size=11,
                      num_experts=8, num_experts_per_tok=2, moe_d_ff=8,
                      moe_capacity_factor=8.0, dtype="float32")
    p = M.moe_init(cfg, jax.random.key(1))
    x = jnp.asarray(rng.normal(0, 0.5, (16, 16)).astype(np.float32))
    full, _ = M.moe_ffn_local(cfg, p, x, jnp.asarray(0), 1)
    m_size = 4
    el = cfg.num_experts // m_size
    partials = []
    for r in range(m_size):
        pr = dict(p)
        pr["w_gate"] = p["w_gate"][r * el:(r + 1) * el]
        pr["w_up"] = p["w_up"][r * el:(r + 1) * el]
        pr["w_down"] = p["w_down"][r * el:(r + 1) * el]
        y, _ = M.moe_ffn_local(cfg, pr, x, jnp.asarray(r), m_size)
        partials.append(y)
    np.testing.assert_allclose(sum(partials), full, rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# pattern segmentation
# ---------------------------------------------------------------------------

def test_segment_pattern_roundtrip():
    for arch, cfg in ARCHS.items():
        segs = segment_pattern(cfg.pattern)
        rebuilt = tuple(k for cyc, reps in segs for _ in range(reps)
                        for k in cyc)
        assert rebuilt == cfg.pattern, arch
        assert len(segs) <= 3, (arch, len(segs))


def test_segment_pattern_examples():
    assert segment_pattern((ATTN,) * 5) == [((ATTN,), 5)]
    assert segment_pattern((ATTN_LOCAL, ATTN) * 3) == [((ATTN_LOCAL, ATTN), 3)]
    assert segment_pattern((ATTN, MOE, MOE)) == [((ATTN,), 1), ((MOE,), 2)]


def test_rope_relative_property(rng):
    """⟨rope(q,i), rope(k,j)⟩ depends only on i−j."""
    hd = 16
    q = jnp.asarray(rng.normal(0, 1, (1, 1, 1, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (1, 1, 1, hd)).astype(np.float32))

    def dot_at(i, j):
        qi = rope(q, jnp.asarray([i]), 10000.0)
        kj = rope(k, jnp.asarray([j]), 10000.0)
        return float(jnp.sum(qi * kj))

    assert abs(dot_at(5, 3) - dot_at(9, 7)) < 1e-4
    assert abs(dot_at(10, 10) - dot_at(0, 0)) < 1e-4
